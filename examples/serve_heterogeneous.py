"""SMS-scheduled serving with a REAL model over a paged KV pool.

  PYTHONPATH=src python examples/serve_heterogeneous.py

Two clients — an interactive chat stream and a bulk tenant whose requests
share a prefix — are scheduled by the three SMS stages into a
continuous-batching engine running a tiny dense model with the Pallas
paged-attention kernel (interpret mode on CPU). Shared-prefix pages are
allocated once and ref-counted (stage-1 "row hits").
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, reduced
from repro.configs.registry import get_config
from repro.serving import paged_lm
from repro.serving.kv_cache import PagedAllocator
from repro.serving.scheduler import SMSScheduler
from repro.serving.types import Request

PAGE = 8
RUN = RunConfig(compute_dtype="float32")


def main():
    cfg = reduced(get_config("qwen1.5-4b"), n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=256)
    params = jax.tree_util.tree_map(
        lambda x: x, __import__("repro.models.registry",
                                fromlist=["get_model"]).get_model(cfg).init(
        jax.random.PRNGKey(0)))
    alloc = PagedAllocator(n_pages=64, page_size=PAGE)
    sched = SMSScheduler(n_clients=2, sjf_prob=0.9, age_cap_ms=5.0)
    pools = paged_lm.init_pools(cfg, n_pages=64, page_size=PAGE)

    # client 0: 3 interactive requests; client 1: 4 bulk with shared prefix
    reqs = []
    rid = 0
    for i in range(3):
        r = Request(rid, 0, prefix_id=-(rid + 1), prompt_len=6, max_new=6,
                    arrival=float(i))
        r.shared_prefix_len = 0
        reqs.append(r)
        rid += 1
    for i in range(4):
        r = Request(rid, 1, prefix_id=42, prompt_len=2 * PAGE + 3, max_new=6,
                    arrival=0.0)
        r.shared_prefix_len = 2 * PAGE
        reqs.append(r)
        rid += 1
    for r in reqs:
        sched.enqueue(r, r.arrival)

    rng = np.random.RandomState(0)
    running = []   # (req, pages, tokens, pos)
    now, finished = 0.0, []
    while len(finished) < len(reqs):
        while len(running) < 4:
            req = sched.pop_admission(now)
            if req is None:
                break
            got = alloc.alloc_seq(req.prompt_len + req.max_new,
                                  req.prefix_id if req.prefix_id >= 0 else
                                  None, prefix_len=req.shared_prefix_len)
            assert got is not None
            pages, n_shared = got
            prompt = list(rng.randint(1, cfg.vocab_size, req.prompt_len))
            running.append([req, pages, prompt, 0])
            print(f"t={now:5.1f} admit r{req.rid} client{req.client} "
                  f"pages={pages[:4]}{'...' if len(pages) > 4 else ''} "
                  f"shared={n_shared}")
        # one decode step for every running sequence (prompt replay = chunked
        # prefill through the same paged step)
        B = len(running)
        tok = jnp.asarray([r[2][r[3]] if r[3] < len(r[2]) else r[2][-1]
                           for r in running], jnp.int32)
        pos = jnp.asarray([r[3] for r in running], jnp.int32)
        n_slots = max(len(r[1]) for r in running)
        pt = jnp.asarray([r[1] + [r[1][-1]] * (n_slots - len(r[1]))
                          for r in running], jnp.int32)
        logits, new_pools = paged_lm.paged_decode_step(
            params, cfg, RUN, pools, tok, pos, pt, page_size=PAGE)
        pools = new_pools
        nxt = np.asarray(jnp.argmax(logits, -1))
        done = []
        for i, r in enumerate(running):
            r[3] += 1
            if r[3] >= len(r[2]):                  # generating
                r[2].append(int(nxt[i]))
            if r[3] >= r[0].prompt_len + r[0].max_new:
                done.append(r)
        for r in done:
            running.remove(r)
            alloc.free_seq(r[1])
            sched.on_finish(r[0])
            finished.append(r[0])
            gen = r[2][r[0].prompt_len:]
            print(f"t={now:5.1f} done  r{r[0].rid} client{r[0].client} "
                  f"generated={gen}")
        now += 1.0
    print(f"\nall {len(finished)} requests served; "
          f"page utilization returned to {alloc.utilization():.0%} "
          f"(prefix pages stay pinned)")


if __name__ == "__main__":
    main()
