"""End-to-end training driver: ~100M-param xLSTM for a few hundred steps.

  PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 300
  PYTHONPATH=src python examples/train_lm.py --smoke        # tiny + fast

Demonstrates: deterministic sharded data, AdamW + cosine schedule, remat,
async atomic checkpointing with resume, straggler detection. On this CPU
container the full 125M model is slow; --smoke runs a reduced config.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import RunConfig, ShapeConfig, reduced
from repro.configs.registry import get_config
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import StragglerPolicy, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        args.steps = min(args.steps, 30)
        args.seq = 128
    run = RunConfig(seq_len=args.seq, global_batch=args.batch,
                    compute_dtype="float32", remat="none", lr=3e-4,
                    warmup_steps=20, total_steps=args.steps)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    trainer = Trainer(cfg, run, make_local_mesh(), shape,
                      ckpt_dir=args.ckpt, ckpt_every=50,
                      straggler=StragglerPolicy(action="report"))
    print(f"arch={cfg.name} params={cfg.n_params() / 1e6:.1f}M "
          f"tokens/step={shape.tokens}")
    state = trainer.train(args.steps)
    for m in trainer.metrics_log[:: max(len(trainer.metrics_log) // 10, 1)]:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"({m['step_time_s']:.2f}s)")
    print(f"final loss {trainer.metrics_log[-1]['loss']:.4f} "
          f"at step {state.step}")
    if trainer.events:
        print("events:", trainer.events)


if __name__ == "__main__":
    main()
