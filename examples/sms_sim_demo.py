"""Inspect the SMS pipeline cycle-by-cycle on a tiny configuration.

  PYTHONPATH=src python examples/sms_sim_demo.py

Shows stage-1 batch formation (per-source FIFOs), stage-2 drains, and the
per-bank DCS occupancy over the first few hundred cycles.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import simulator as sim
from repro.core.params import SimConfig


def main():
    cfg = SimConfig(n_cpu=2, n_channels=1, buf_entries=28, fifo_size=6,
                    dcs_size=4)
    pool = {
        "mpki": np.asarray([30.0, 5.0, 1000.0], np.float32),
        "inst_per_miss": np.asarray([33.3, 200.0, 1.0], np.float32),
        "rbl": np.asarray([0.3, 0.8, 0.93], np.float32),
        "blp": np.asarray([4, 1, 4], np.int32),
        "is_gpu": np.asarray([False, False, True]),
    }
    active = np.ones(3, bool)
    st, sms, dram = sim.simulate_debug(cfg, "sms", pool, active,
                                       n_cycles=600)
    names = ["cpu.hi-blp", "cpu.hi-rbl", "gpu"]
    print("after 600 cycles:")
    print(f"{'source':11s} {'emitted':>8s} {'completed':>9s} "
          f"{'rowhits':>8s} {'issued':>7s} {'fifo_len':>8s}")
    for s, n in enumerate(names):
        print(f"{n:11s} {st['emitted'][s]:8d} {st['completed'][s]:9d} "
              f"{dram['hits'][s]:8d} {dram['issued'][s]:7d} "
              f"{sms['f_len'][0, s]:8d}")
    print(f"\nDCS per-bank queue lengths: {sms['d_len'][0].tolist()}")
    print(f"open rows per bank:        {dram['open_row'][0].tolist()}")
    gpu_rbl = dram['hits'][2] / max(dram['issued'][2], 1)
    print(f"\nGPU row-hit rate under SMS batching: {gpu_rbl:.2f} "
          f"(generator locality 0.93 — stage-1 batches preserve it)")


if __name__ == "__main__":
    main()
