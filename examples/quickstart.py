"""Quickstart: the paper's SMS vs the baselines, in ~50 lines.

  PYTHONPATH=src python examples/quickstart.py

Every scheduler is a `MemoryPolicy` object in a registry
(`repro.core.policy`); `simulator.POLICIES` is just the registry's
enumeration. Writing a new policy is: subclass `CentralizedPolicy`, override
`score` (and optionally `extra_state` / `policy_tick` / `on_issue`),
decorate with `@policy.register` — the simulator, every benchmark sweep, and
the invariant tests pick it up by name with no other changes. `Oldest`
below is a complete example.
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import metrics as met
from repro.core import policy
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import SimConfig
from repro.core.schedulers import CentralizedPolicy, base_score


@policy.register
class Oldest(CentralizedPolicy):
    """Pure FCFS: age only, ignoring row hits — a 5-line custom policy."""

    name = "oldest"

    def score(self, cfg, pool, buf, is_hit, t):
        return base_score(cfg, buf, 0 * is_hit, t)


def main():
    # 4 CPU cores + 1 GPU sharing 2 memory channels, high-intensity mix
    cfg = SimConfig(n_cpu=4, n_channels=2, buf_entries=72, fifo_size=8,
                    dcs_size=4)
    wls = [w for w in wl.make_workloads(cfg.n_cpu, n_per_cat=3, seed=0)
           if w.category in ("H", "HM")]
    pool, active = wl.pool_batch(cfg, wls)
    apool, aactive, amap = wl.alone_batch(cfg)

    print(f"{len(wls)} workloads x {cfg.n_src} sources, "
          f"{cfg.n_channels} channels\n")
    print(f"{'policy':12s} {'WS':>6s} {'cpuWS':>6s} {'gpuSU':>6s} {'maxSD':>6s}")
    # registry enumeration: the built-ins + the Oldest policy defined above
    for pol in policy.names():
        am = sim.simulate(cfg, pol, apool, aactive, 8_000, 1_000)
        alone = wl.alone_perf_lookup(cfg, am, amap)
        m = sim.simulate(cfg, pol, pool, active, 8_000, 1_000)
        perf = sim.perf_vector(cfg, m, pool)
        rows = [met.workload_metrics(cfg, w, perf[i], alone)
                for i, w in enumerate(wls)]
        a = met.aggregate(rows)
        print(f"{pol:12s} {a['weighted_speedup']:6.3f} "
              f"{a['cpu_weighted_speedup']:6.3f} {a['gpu_speedup']:6.3f} "
              f"{a['max_slowdown']:6.2f}")
    print("\nExpected: SMS best WS and (much) best max-slowdown — the "
          "paper's Fig 4 in miniature.")


if __name__ == "__main__":
    main()
