"""End-to-end system behaviour: the paper's headline orderings hold.

These are the reproduction's acceptance tests: on a contended heterogeneous
workload, SMS must beat the centralized schedulers on fairness and system
performance, while FR-FCFS must show the GPU-favoring unfairness the paper
starts from.
"""
import numpy as np
import pytest

from repro.core import metrics as met
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import SimConfig

CFG = SimConfig(n_cpu=4, n_channels=2, buf_entries=72, fifo_size=8,
                dcs_size=4)
CYCLES, WARMUP = 6_000, 800


@pytest.fixture(scope="module")
def contended_results():
    wls = [w for w in wl.make_workloads(CFG.n_cpu, n_per_cat=3, seed=3)
           if w.category in ("H", "HM", "HL")]
    pool, active = wl.pool_batch(CFG, wls)
    apool, aactive, amap = wl.alone_batch(CFG)
    out = {}
    for pol in sim.POLICIES:
        am = sim.simulate(CFG, pol, apool, aactive, CYCLES, WARMUP)
        alone = wl.alone_perf_lookup(CFG, am, amap)
        m = sim.simulate(CFG, pol, pool, active, CYCLES, WARMUP)
        perf = sim.perf_vector(CFG, m, pool)
        rows = [met.workload_metrics(CFG, w, perf[i], alone)
                for i, w in enumerate(wls)]
        out[pol] = met.aggregate(rows)
    return out


def test_sms_best_fairness(contended_results):
    r = contended_results
    for pol in ("frfcfs", "atlas", "parbs", "tcm"):
        assert r["sms"]["max_slowdown"] < r[pol]["max_slowdown"], \
            f"SMS fairness not better than {pol}: {r}"


def test_sms_best_system_performance(contended_results):
    r = contended_results
    for pol in ("frfcfs", "atlas", "parbs", "tcm"):
        assert r["sms"]["weighted_speedup"] > r[pol]["weighted_speedup"], \
            f"SMS weighted speedup not better than {pol}"


def test_sms_cpu_speedup_over_tcm(contended_results):
    r = contended_results
    assert r["sms"]["cpu_weighted_speedup"] > \
        r["tcm"]["cpu_weighted_speedup"]


def test_sms_defends_cpus_vs_frfcfs(contended_results):
    """FR-FCFS lets the high-RBL GPU crowd out CPUs relative to SMS."""
    r = contended_results
    assert r["sms"]["cpu_max_slowdown"] < r["frfcfs"]["cpu_max_slowdown"]


def test_all_policies_make_progress(contended_results):
    for pol, agg in contended_results.items():
        assert agg["weighted_speedup"] > 0.5, f"{pol} made no progress"
