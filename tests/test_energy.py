"""DRAM energy subsystem (`repro.core.energy`): accounting identities,
power-down state machine, strict additivity, and the metrics/power surface.

The contract under test:

  * command energy is exact: e_rw = energy_rw * issued per source, e_act =
    energy_act * (issued - hits); background = standby/power-down split by
    pd_cycles — no drift, no double-charging;
  * the power-down machine engages on genuinely idle channels (and pays a
    wake-up on the next command), but stays out of the way under load;
  * the subsystem is PURELY additive: disabling it changes no scheduling
    metric, and enabling it adds only the energy keys (the golden-digest
    tests cover bit-identity; here we cover the metric surface both ways);
  * energy flows unchanged through the stacked cross-policy path.
"""
import numpy as np
import pytest

from repro.core import energy, engine
from repro.core import metrics as met
from repro.core import power
from repro.core import simulator as sim
from repro.core.params import SimConfig

CFG = SimConfig(n_cpu=3, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3)
N_CYCLES = 3_000


def _pool(rng: np.random.RandomState, cfg: SimConfig):
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    return {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
    }


@pytest.mark.parametrize("policy", ["frfcfs", "atlas", "sms"])
def test_command_energy_identities(policy):
    """Raw counters satisfy the per-command accounting identities exactly."""
    pool = _pool(np.random.RandomState(0), CFG)
    _, _, dram_f = sim.simulate_debug(CFG, policy, pool,
                                      np.ones(CFG.n_src, bool), N_CYCLES)
    issued = dram_f["issued"].astype(np.float64)
    hits = dram_f["hits"].astype(np.float64)
    # f32 accumulation of a non-dyadic increment rounds each add: tolerance
    # covers ~N ulps over thousands of accumulated commands
    np.testing.assert_allclose(dram_f["e_rw"], CFG.energy_rw * issued,
                               rtol=1e-4)
    np.testing.assert_allclose(dram_f["e_act"],
                               CFG.energy_act * (issued - hits), rtol=1e-4)
    # background is now two integer counters (exact by construction — the
    # variable-step driver accrues skipped spans in one add): every channel
    # cycle is either standby or power-down, never both or neither
    pd = int(dram_f["pd_cycles"].sum())
    sb = int(dram_f["sb_cycles"].sum())
    assert sb + pd == CFG.n_channels * N_CYCLES, (sb, pd)
    assert (dram_f["e_wake"] >= 0).all()
    assert issued.sum() > 0, "vacuous run: nothing issued"


def test_power_down_engages_on_idle_and_stays_out_under_load():
    cfg = CFG
    pool = _pool(np.random.RandomState(1), cfg)
    # one sparse CPU source alone: long all-banks-idle stretches between
    # misses -> power-down cycles and wake-up penalties accrue
    pool["mpki"][:] = 2.0
    pool["inst_per_miss"][:] = 500.0
    lone = np.zeros(cfg.n_src, bool)
    lone[0] = True
    _, _, dram_idle = sim.simulate_debug(cfg, "frfcfs", pool, lone, N_CYCLES)
    pd_frac = dram_idle["pd_cycles"].sum() / (cfg.n_channels * N_CYCLES)
    assert pd_frac > 0.5, f"idle system never powered down: {pd_frac:.2f}"
    assert dram_idle["e_wake"].sum() > 0, "woke without paying the penalty"
    # full mix incl. the streaming GPU: channels stay busy
    busy_pool = _pool(np.random.RandomState(2), cfg)
    _, _, dram_busy = sim.simulate_debug(cfg, "frfcfs", busy_pool,
                                         np.ones(cfg.n_src, bool), N_CYCLES)
    busy_frac = dram_busy["pd_cycles"].sum() / (cfg.n_channels * N_CYCLES)
    assert busy_frac < 0.05, f"loaded system powered down: {busy_frac:.2f}"
    bg = lambda d: CFG.energy_standby * float(d["sb_cycles"].sum()) \
        + CFG.energy_pd * float(d["pd_cycles"].sum())
    assert bg(dram_busy) > bg(dram_idle), \
        "standby must cost more than power-down"


@pytest.mark.parametrize("policy", ["frfcfs", "sms"])
def test_energy_is_purely_additive_to_metrics(policy):
    """Flipping energy_enabled changes no scheduling metric, only adds the
    energy outputs (simulate path; golden digests pin the raw-state side)."""
    rng = np.random.RandomState(3)
    W, S = 2, CFG.n_src
    pool = {k: np.stack([v, v]) for k, v in _pool(rng, CFG).items()}
    active = np.ones((W, S), bool)
    on = sim.simulate(CFG, policy, pool, active, 1_000, 200)
    off = sim.simulate(CFG.replace(energy_enabled=False), policy, pool,
                       active, 1_000, 200)
    energy_keys = {"energy_act", "energy_rw", "energy_bg", "energy_wake",
                   "pd_cycles"}
    assert set(on) - set(off) == energy_keys
    for k in off:
        np.testing.assert_array_equal(on[k], off[k], err_msg=k)
    assert sum(float(np.sum(on[k])) for k in energy_keys) > 0


def test_disabled_mode_leaves_no_trace():
    cfg = CFG.replace(energy_enabled=False)
    assert energy.energy_state(cfg) == {}
    assert not set(energy.STATE_KEYS) & set(engine.dram_state(cfg))


def test_energy_flows_through_stacked_path():
    """Stacked slices carry the counters bit-identically to standalone."""
    rng = np.random.RandomState(4)
    W, S = 2, CFG.n_src
    pool = {k: np.stack([v, v]) for k, v in _pool(rng, CFG).items()}
    active = np.ones((W, S), bool)
    fam = sim.stackable_names(CFG)[:3]
    stk = sim.simulate_stacked(CFG, fam, pool, active, 500, 100)
    for pol in fam:
        ref = sim.simulate(CFG, pol, pool, active, 500, 100)
        for k in ("energy_act", "energy_rw", "energy_bg", "energy_wake",
                  "pd_cycles"):
            np.testing.assert_array_equal(ref[k], stk[pol][k],
                                          err_msg=f"{pol}:{k}")


def test_energy_breakdown_and_full_mc_combine():
    rng = np.random.RandomState(5)
    W, S = 2, CFG.n_src
    pool = {k: np.stack([v, v]) for k, v in _pool(rng, CFG).items()}
    active = np.ones((W, S), bool)
    n_cycles = 1_500
    m = sim.simulate(CFG, "frfcfs", pool, active, n_cycles, 200)
    spc = power.scheduler_static_power(CFG, "frfcfs")
    assert spc > 0
    br = met.energy_breakdown(CFG, m, pool, n_cycles, static_per_cycle=spc)
    for k, v in br.items():
        assert np.asarray(v).shape == (W,), k
        assert np.isfinite(v).all(), k
    dyn = (m["energy_act"] + m["energy_rw"]).sum(-1)
    np.testing.assert_allclose(
        br["energy_total"],
        dyn + m["energy_bg"] + m["energy_wake"] + spc * n_cycles, rtol=1e-6)
    np.testing.assert_allclose(
        br["energy_dyn_cpu"] + br["energy_dyn_gpu"], dyn, rtol=1e-6)
    reqs = m["completed"].sum(-1)
    np.testing.assert_allclose(
        br["edp"], br["energy_per_request"] * n_cycles / reqs, rtol=1e-6)
    assert ((br["act_energy_frac"] > 0) & (br["act_energy_frac"] < 1)).all()
    # the full-MC combine agrees with the breakdown's per-request figure
    fm = power.full_mc_energy(CFG, "frfcfs", float(dyn[0]),
                              float(m["energy_bg"][0] + m["energy_wake"][0]),
                              n_cycles, float(reqs[0]))
    np.testing.assert_allclose(fm["energy_per_request_nj"],
                               br["energy_per_request"][0], rtol=1e-6)
    # SMS's FIFO-only structures must undercut the CAM scheduler's leakage
    assert power.scheduler_static_power(CFG, "sms") < spc
