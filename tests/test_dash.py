"""SMS-DASH deadline extension: accounting invariants + effectiveness."""
import numpy as np
import pytest

from repro.core import simulator as sim
from repro.core.params import SimConfig


def _setup(reqs=45):
    cfg = SimConfig(n_cpu=4, n_gpu=2, n_channels=2, buf_entries=72,
                    fifo_size=8, dcs_size=4)
    mpki = np.array([30, 38, 25, 33, 1000, 1000], np.float32)
    pool = {
        "mpki": mpki, "inst_per_miss": np.maximum(1000 / mpki, 1),
        "rbl": np.array([.5, .45, .6, .55, .9, .85], np.float32),
        "blp": np.array([3, 4, 2, 5, 4, 4], np.int32),
        "is_gpu": np.array([0, 0, 0, 0, 1, 0], bool),
        "dl_period": np.array([0, 0, 0, 0, 0, 1000], np.int32),
        "dl_reqs": np.array([0, 0, 0, 0, 0, reqs], np.int32),
    }
    return cfg, {k: v[None] for k, v in pool.items()}


@pytest.fixture(scope="module")
def dash_runs():
    cfg, pb = _setup()
    active = np.ones((1, cfg.n_src), bool)
    return cfg, {pol: sim.simulate(cfg, pol, pb, active, 10_000, 2_000)
                 for pol in ("sms", "sms_dash", "frfcfs")}


def test_frame_accounting(dash_runs):
    """met + missed == elapsed frames, and only for deadline sources."""
    cfg, runs = dash_runs
    for pol, m in runs.items():
        frames = m["dl_met"][0] + m["dl_missed"][0]
        assert frames[5] == 10, f"{pol}: {frames[5]} frames counted"
        assert (frames[:5] == 0).all(), f"{pol}: non-deadline src counted"


def test_dash_meets_more_deadlines(dash_runs):
    cfg, runs = dash_runs
    dash = int(runs["sms_dash"]["dl_met"][0, 5])
    plain = int(runs["sms"]["dl_met"][0, 5])
    fr = int(runs["frfcfs"]["dl_met"][0, 5])
    assert dash > plain, (dash, plain)
    assert dash > fr, (dash, fr)
    assert dash >= 5, f"sms_dash met only {dash}/10"


def test_dash_preserves_cpu_progress(dash_runs):
    """Deadline enforcement must not collapse CPU throughput (<35% cost)."""
    cfg, runs = dash_runs
    cpu_dash = float(runs["sms_dash"]["ipc"][0, :4].mean())
    cpu_sms = float(runs["sms"]["ipc"][0, :4].mean())
    assert cpu_dash > 0.65 * cpu_sms


def test_deadline_sources_respect_demand_cap():
    """Accelerator emission is bounded by its per-frame demand."""
    cfg, pb = _setup(reqs=20)
    active = np.ones((1, cfg.n_src), bool)
    m = sim.simulate(cfg, "sms_dash", pb, active, 10_000, 2_000)
    # ~20 requests/frame demanded -> emission rate <= ~20/1000 cycles
    assert float(m["mpkc"][0, 5]) <= 22.0
