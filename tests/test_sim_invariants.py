"""Simulator invariants (unit + hypothesis property tests).

Conservation: every emitted request is exactly one of {completed, waiting in
an MC structure, pending at the core}. Structural bounds: FIFO lengths within
capacity, non-negative stats. Physical bounds: data-bus occupancy can never
exceed 1 burst per t_burst cycles per channel.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep; pip install -r "
                    "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import simulator as sim
from repro.core.params import SimConfig

CFG = SimConfig(n_cpu=3, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3)


def _pool(rng: np.random.RandomState, cfg: SimConfig, with_deadline=False):
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
        "dl_period": np.zeros(S, np.int32),
        "dl_reqs": np.zeros(S, np.int32),
    }
    if with_deadline and cfg.n_cpu >= 2:
        # turn one "cpu" slot into a frame-deadline accelerator
        pool["dl_period"][0] = int(rng.randint(300, 900))
        pool["dl_reqs"][0] = int(rng.randint(5, 40))
    return pool


def _conservation(cfg, st_f, sched_f, dram_f, policy):
    emitted = st_f["emitted"].astype(np.int64)
    completed = st_f["completed"].astype(np.int64)
    pending = st_f["pend_valid"].astype(np.int64)
    in_ring = dram_f["ring"].sum(0).astype(np.int64)
    S = cfg.n_src
    in_struct = np.zeros(S, np.int64)
    if policy.startswith("sms"):
        for s in range(S):
            in_struct[s] += sched_f["f_len"][:, s].sum()
        d_src, d_len, d_head = (sched_f["d_src"], sched_f["d_len"],
                                sched_f["d_head"])
        C, B, D = d_src.shape
        for c in range(C):
            for b in range(B):
                for i in range(d_len[c, b]):
                    in_struct[d_src[c, b, (d_head[c, b] + i) % D]] += 1
    else:
        for c in range(cfg.n_channels):
            for e in range(cfg.buf_entries):
                if sched_f["valid"][c, e]:
                    in_struct[sched_f["src"][c, e]] += 1
    lhs = emitted
    rhs = completed + pending + in_ring + in_struct
    np.testing.assert_array_equal(lhs, rhs)


@pytest.mark.parametrize("policy", sim.POLICIES)
def test_request_conservation(policy):
    rng = np.random.RandomState(0)
    pool = _pool(rng, CFG)
    active = np.ones(CFG.n_src, bool)
    st_f, sched_f, dram_f = sim.simulate_debug(CFG, policy, pool, active,
                                               n_cycles=3_000)
    _conservation(CFG, st_f, sched_f, dram_f, policy)
    assert (st_f["outstanding"] >= 0).all()
    assert (st_f["outstanding"] ==
            st_f["emitted"] - st_f["completed"]).all()


@pytest.mark.parametrize("policy", ["sms", "frfcfs"])
def test_bus_capacity_bound(policy):
    """Completions can't exceed the data-bus capacity (1 / t_burst / chan)."""
    rng = np.random.RandomState(1)
    pool = _pool(rng, CFG)
    active = np.ones(CFG.n_src, bool)
    n_cycles = 4_000
    st_f, _, dram_f = sim.simulate_debug(CFG, policy, pool, active, n_cycles)
    total = int(st_f["completed"].sum())
    cap = n_cycles * CFG.n_channels / CFG.timing.t_burst
    assert total <= cap * 1.01


def test_sms_structure_bounds():
    rng = np.random.RandomState(2)
    pool = _pool(rng, CFG)
    active = np.ones(CFG.n_src, bool)
    _, sms_f, _ = sim.simulate_debug(CFG, "sms", pool, active, 3_000)
    assert (sms_f["f_len"] >= 0).all() and \
        (sms_f["f_len"] <= CFG.fifo_size).all()
    assert (sms_f["d_len"] >= 0).all() and \
        (sms_f["d_len"] <= CFG.dcs_size).all()
    assert (sms_f["drain_left"] >= 0).all()


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000),
       st.sampled_from(["sms", "sms_dash", "tcm", "frfcfs"]))
def test_conservation_property(seed, policy):
    """Hypothesis: conservation holds for random source parameterizations."""
    rng = np.random.RandomState(seed)
    cfg = SimConfig(n_cpu=int(rng.randint(2, 5)), n_channels=1,
                    buf_entries=16, fifo_size=4, dcs_size=2)
    pool = _pool(rng, cfg, with_deadline=(policy == "sms_dash"))
    active = rng.rand(cfg.n_src) < 0.8
    active[-1] = True
    active[0] = True
    st_f, sched_f, dram_f = sim.simulate_debug(cfg, policy, pool, active,
                                               n_cycles=1_500)
    _conservation(cfg, st_f, sched_f, dram_f, policy)


def test_inactive_sources_stay_silent():
    rng = np.random.RandomState(3)
    pool = _pool(rng, CFG)
    active = np.zeros(CFG.n_src, bool)
    active[0] = True
    st_f, _, _ = sim.simulate_debug(CFG, "sms", pool, active, 2_000)
    assert st_f["emitted"][1:].sum() == 0
    assert st_f["emitted"][0] > 0


def test_rbl_measured_tracks_generator():
    """High-RBL source measured row-hit rate >> low-RBL source (alone)."""
    from repro.core import workloads as wl
    cfg = SimConfig(n_cpu=1, n_channels=1, buf_entries=16, fifo_size=8,
                    dcs_size=4)
    for rbl, lo, hi in ((0.9, 0.6, 1.0), (0.2, 0.0, 0.45)):
        pool = {
            "mpki": np.asarray([40.0, 40.0], np.float32),
            "inst_per_miss": np.asarray([25.0, 25.0], np.float32),
            "rbl": np.asarray([rbl, rbl], np.float32),
            "blp": np.asarray([2, 2], np.int32),
            "is_gpu": np.asarray([False, True]),
        }
        m = sim.simulate(cfg, "frfcfs", {k: v[None] for k, v in pool.items()},
                         np.asarray([[True, False]]), 6_000, 500)
        measured = float(m["rbl"][0, 0])
        assert lo <= measured <= hi, f"rbl={rbl} measured={measured}"
