"""Flight-recorder contract: the in-loop telemetry ring is free when off,
invisible when on, and exact under the variable-step driver.

Five layers:

  * OFF is absent — no `tl_*` state, and the per-cycle jaxpr traces with
    every telemetry entry point poisoned (so a leak raises at trace time),
    for both the ticked and the skipping driver. A twin test proves the
    poison actually fires when telemetry is ON, so the gate is not vacuous;
  * ON never changes a decision — with the recorder enabled, every
    non-telemetry final-state array is bit-identical to the telemetry-off
    run, for every registry policy, through the skipping driver;
  * driver-invariance — ticked and skipping runs produce bit-identical
    rings on every policy once the `steps` skip-meter channel is sliced
    off (`telemetry.N_INVARIANT`), and `steps` itself counts exactly the
    processed driver steps (the satellite skip-meter contract backing
    simspeed's ``cycles_per_s`` vs ``steps_per_s`` split);
  * stacked slices match solo runs — the ring rides the stacked carry;
  * the host-side views (`metrics.timeline_breakdown`) and the perf-trend
    ledger (`benchmarks.bench_trend`) hold their accounting identities.
"""
import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, telemetry
from repro.core import metrics as met
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import CLS_CPU, CLS_GPU, CLS_HWA, SimConfig

BASE = SimConfig(n_cpu=3, n_gpu=1, n_hwa=1, n_channels=2, buf_entries=24,
                 fifo_size=5, dcs_size=3)
# window * epoch = 1024 cycles retained >= every run length below: the ring
# holds the WHOLE run, so whole-run accounting identities are exact
CFG = BASE.replace(telemetry_enabled=True, telemetry_window=16,
                   telemetry_epoch=64)
N_CYCLES = 900
ALL_POLICIES = list(policy_api.names())


def _mix_pool():
    """(W=2, S=5) batch: row 0 busy 3-class mix, row 1 sparse/idle-heavy
    (spans form, so `skip_accrue` is actually exercised)."""
    mpki = np.array([[25, 40, 18, 1000, 1000],
                     [0.5, 1.0, 0.8, 1000, 1000]], np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": np.tile(np.array([.5, .4, .6, .9, .85], np.float32), (2, 1)),
        "blp": np.tile(np.array([3, 2, 4, 4, 2], np.int32), (2, 1)),
        "is_gpu": np.tile(np.array([0, 0, 0, 1, 0], bool), (2, 1)),
        "src_class": np.tile(np.array(
            [CLS_CPU] * 3 + [CLS_GPU, CLS_HWA], np.int32), (2, 1)),
        "dl_period": np.tile(np.array([0, 0, 0, 0, 400], np.int32), (2, 1)),
        "dl_reqs": np.tile(np.array([0, 0, 0, 0, 20], np.int32), (2, 1)),
        "dl_jitter": np.tile(np.array([0, 0, 0, 0, 10], np.int32), (2, 1)),
    }
    active = np.array([[1, 1, 1, 1, 1],
                       [1, 1, 0, 0, 1]], bool)
    return pool, active


def _row(pool, active, i):
    return {k: v[i] for k, v in pool.items()}, active[i]


def _digest(tree):
    out = {}
    for key in sorted(tree):
        if key.startswith("_"):
            continue
        v = np.ascontiguousarray(tree[key])
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
        out[key] = h.hexdigest()
    return out


def _stackable(cfg):
    return [n for n in ALL_POLICIES if policy_api.is_stackable(n, cfg)]


def _trace_both_drivers(cfg):
    """Trace the per-cycle step AND the skip body for frfcfs under cfg."""
    pool, active = _mix_pool()
    pool = sim.prepare_pool(_row(pool, active, 0)[0], (cfg.n_src,))
    cfg, pol, carry = sim._init(cfg, "frfcfs")
    active = jnp.ones((cfg.n_src,), bool)
    step = policy_api.make_step(cfg, pol, pool, active)
    jax.make_jaxpr(step)(carry, jnp.int32(5))
    skip = policy_api.make_skip_step(cfg, pol, pool, active)
    jax.make_jaxpr(lambda c, t: skip(c, t, jnp.int32(400)))(carry,
                                                            jnp.int32(5))


# ---------------------------------------------------------------------------
# (a) OFF is absent: no state, no primitives (poisoned entry points)
# ---------------------------------------------------------------------------

def test_off_no_state_and_zero_primitives(monkeypatch):
    """With the gate off there is no `tl_*` state, and tracing both driver
    bodies with every telemetry entry point replaced by a raiser succeeds:
    the off path contains no telemetry call at all."""
    assert not set(telemetry.STATE_KEYS) & set(engine.dram_state(BASE))

    def boom(*a, **k):
        raise AssertionError("telemetry entry point reached while off")
    for fn in ("snapshot", "tick_accrue", "skip_accrue"):
        monkeypatch.setattr(telemetry, fn, boom)
    _trace_both_drivers(BASE)                     # must not raise


def test_poison_fires_when_on(monkeypatch):
    """Non-vacuity twin: the same poison DOES fire when telemetry is on,
    so the zero-primitives test above is actually load-bearing."""
    def boom(*a, **k):
        raise AssertionError("telemetry entry point reached")
    monkeypatch.setattr(telemetry, "snapshot", boom)
    with pytest.raises(AssertionError, match="entry point reached"):
        _trace_both_drivers(CFG)


# ---------------------------------------------------------------------------
# (b) ON never changes a decision: off-vs-on final state bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_on_is_measurement_only(pol):
    """Every non-telemetry array of the final raw state is bit-identical
    between telemetry-off and telemetry-on runs, through the SKIPPING
    driver on the sparse row (both `tick_accrue` and `skip_accrue` run)."""
    assert CFG.energy_enabled and CFG.qos_enabled
    pool, active = _mix_pool()
    pool1, act1 = _row(pool, active, 1)
    ref = sim.simulate_debug(BASE, pol, pool1, act1, N_CYCLES, skip=True)
    got = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES, skip=True)
    for part, (r, g) in zip(("src", "sched", "dram"), zip(ref, got)):
        rd, gd = _digest(r), _digest(g)
        assert set(gd) - set(rd) <= set(telemetry.STATE_KEYS), \
            f"{pol} {part} grew unexpected keys: {set(gd) - set(rd)}"
        for k in rd:
            assert gd[k] == rd[k], f"{pol} {part}[{k}] diverged"
    assert "tl_ring" in got[2], "telemetry state missing — vacuous"


# ---------------------------------------------------------------------------
# (c) driver-invariance: ticked vs skipping rings, and the skip meter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_ring_bit_identical_ticked_vs_skipping(pol):
    """All channels before `steps` are driver-invariant (bit-identical
    between the ticked scan and the event-skipping while_loop); `steps`
    counts exactly the processed steps of whichever driver ran."""
    pool, active = _mix_pool()
    pool1, act1 = _row(pool, active, 1)
    ref = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES, skip=False)
    got = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES, skip=True)
    r_ring, g_ring = ref[2]["tl_ring"], got[2]["tl_ring"]
    np.testing.assert_array_equal(
        r_ring[:, :telemetry.N_INVARIANT],
        g_ring[:, :telemetry.N_INVARIANT],
        err_msg=f"{pol}: ring diverged between drivers")
    assert ref[2]["tl_epoch"] == got[2]["tl_epoch"]
    steps = telemetry.CH["steps"]
    assert r_ring[:, steps].sum() == N_CYCLES, pol
    assert g_ring[:, steps].sum() <= N_CYCLES, pol
    if pol in ("frfcfs", "atlas", "parbs"):       # known to skip here
        assert g_ring[:, steps].sum() < N_CYCLES, \
            f"{pol}: no spans formed — driver-invariance check is vacuous"


def test_accounting_identities_whole_run():
    """Window covers the run, so ring-channel sums equal whole-run totals:
    issues per class match the final per-source issue counters, row hits
    match the hit counter, `steps` matches the cycle count (ticked)."""
    pool, active = _mix_pool()
    pool0, act0 = _row(pool, active, 0)
    st_f, _, dram_f = sim.simulate_debug(CFG, "frfcfs", pool0, act0,
                                         N_CYCLES, skip=False)
    ring = dram_f["tl_ring"]
    cls = np.asarray(sim.prepare_pool(pool0, (CFG.n_src,))["src_class"])
    issued = np.asarray(dram_f["issued"])
    for c, name in ((CLS_CPU, "iss_cpu"), (CLS_GPU, "iss_gpu"),
                    (CLS_HWA, "iss_hwa")):
        assert ring[:, telemetry.CH[name]].sum() == issued[cls == c].sum()
    assert ring[:, telemetry.CH["row_hits"]].sum() == \
        np.asarray(dram_f["hits"]).sum()
    assert ring[:, telemetry.CH["steps"]].sum() == N_CYCLES


def test_skip_meter_agrees_with_sim_steps_on_bursty_archetypes():
    """Satellite contract behind simspeed's throughput split: the
    ``sim_steps`` metric (denominator of ``steps_per_s``, numerator of the
    reported skip ratio) equals the ring's `steps` channel — the driver's
    own processed-step counter — per workload, on the bursty archetype
    batch; the ticked driver pins both at exactly `n_cycles`."""
    cfg = CFG.replace(n_hwa=2)
    pool, active = wl.bursty_batch(cfg)
    n_cycles = 768                                # 12 epochs, window covers
    for skip in (False, True):
        m = sim.simulate(cfg, "frfcfs", pool, active, n_cycles=n_cycles,
                         warmup=0, skip=skip)
        steps_ch = np.asarray(m["telemetry"])[..., telemetry.CH["steps"]]
        per_wl = steps_ch.sum(axis=-1)
        np.testing.assert_array_equal(per_wl, np.asarray(m["sim_steps"]))
        ratio = 1.0 - np.asarray(m["sim_steps"]) / n_cycles
        if skip:
            assert ratio.max() > 0.2, \
                f"no archetype skipped ({ratio}) — the meter is untested"
        else:
            np.testing.assert_array_equal(ratio, np.zeros_like(ratio))


# ---------------------------------------------------------------------------
# (d) stacked slices match solo runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("skip", [False, True], ids=["tick", "skip"])
def test_stacked_ring_matches_solo(skip):
    pool, active = _mix_pool()
    pool1, act1 = _row(pool, active, 1)
    fam = _stackable(CFG)
    out = sim.simulate_debug_stacked(CFG, fam, pool1, act1, N_CYCLES,
                                     skip=skip)
    for pol, (_, _, dram) in out.items():
        solo = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES,
                                  skip=skip)[2]
        # the stacked skipping loop shares one step count across the
        # family, so `steps` is compared only on the ticked path
        n = telemetry.K if not skip else telemetry.N_INVARIANT
        np.testing.assert_array_equal(
            dram["tl_ring"][:, :n], solo["tl_ring"][:, :n],
            err_msg=f"{pol}: stacked ring slice != solo")
        assert dram["tl_epoch"] == solo["tl_epoch"], pol


# ---------------------------------------------------------------------------
# (e) host-side views and the perf-trend ledger
# ---------------------------------------------------------------------------

def test_timeline_breakdown_shapes_and_identities():
    pool, active = _mix_pool()
    total = 300 + 600
    m = sim.simulate(CFG, "frfcfs", pool, active, n_cycles=600, warmup=300,
                     skip=False)
    tb = met.timeline_breakdown(CFG, m, total_cycles=total)
    W = CFG.telemetry_window
    for k, v in tb.items():
        assert v.shape == (2, W), (k, v.shape)
    v = tb["valid"][0].astype(bool)
    assert v.any()
    ep = tb["epoch"][0][v]
    assert (np.diff(ep) == 1).all(), "epochs not contiguous ascending"
    assert (tb["occ_cpu"][..., v] >= 0).all()
    assert (tb["row_hit_rate"][..., v] <= 1.0 + 1e-6).all()
    # ticked run: every in-window cycle is a processed step
    np.testing.assert_allclose(tb["skip_ratio"][..., v], 0.0, atol=1e-6)


def test_bench_trend_check_and_ledger(tmp_path):
    from benchmarks import bench_trend

    def entry(cps, scale_cycles=1000):
        return {"ts": "t", "kind": "simspeed", "label": "x",
                "sweep": {"cycles_per_s": cps, "wall_s": 1.0},
                "scale": {"n_cycles": scale_cycles, "warmup": 10},
                "meta": {}}

    ledger = tmp_path / "ledger.jsonl"
    bench_trend.append_entry(entry(100.0), ledger)
    bench_trend.append_entry(entry(120.0), ledger)
    ledger.open("a").write("{corrupt\n")           # must be skipped, not fatal
    entries = bench_trend.load_ledger(ledger)
    assert len(entries) == 2
    ok, msg = bench_trend.check(entry(100.0), entries)       # -16.7% vs 120
    assert ok and "OK" in msg
    ok, msg = bench_trend.check(entry(90.0), entries)        # -25% vs 120
    assert not ok and "REGRESSION" in msg
    ok, msg = bench_trend.check(entry(50.0, scale_cycles=999), entries)
    assert ok and "nothing to compare" in msg      # scale mismatch: vacuous
    assert bench_trend.entry_from_summary({"no_sweep": 1}) is None
    e = bench_trend.entry_from_summary(
        {"sweep": {"cycles_per_s": 5.0, "wall_s": 2.0},
         "meta": {"sweep_scale": {"n_cycles": 7}, "jax": "x"}},
        kind="smoke", label="l")
    assert e["scale"] == {"n_cycles": 7} and e["kind"] == "smoke"


def test_committed_ledger_parses_and_passes():
    """The repo's seeded ledger must parse, and the committed
    BENCH_simspeed.json snapshot must hold its pace against it."""
    from benchmarks import bench_trend
    entries = bench_trend.load_ledger()
    assert entries, "BENCH_history.jsonl missing or empty"
    cand = bench_trend.candidate_from_bench()
    assert cand is not None
    ok, msg = bench_trend.check(cand, entries)
    assert ok, msg
