"""The self-checking contract: the invariant sanitizer stays silent on
healthy runs and fires on every registered fault.

Three layers:

  * zero-violation sweeps — every registry policy, ticked AND variable-
    step, solo AND stacked, default AND non-default knob points, with
    energy+QoS accounting on;
  * property tests over randomized pools/knobs/drivers (hypothesis when
    the container ships it, a seeded fallback sampler otherwise — the
    property is identical);
  * falsifiability — each fault in `repro.core.faults` must flip one of
    its targeted counters, and the `checkify` hard-fail mode must raise
    on a faulted run while staying quiet on a clean one.

Fault runs go through `simulate_debug`/`simulate_debug_stacked` ONLY:
those build a fresh program per call, so a monkeypatched engine function
is actually traced instead of served from the cached `_sim_batch` jit.
"""
import numpy as np
import pytest

from repro.core import faults, validate
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core.params import N_CLASSES, SimConfig

CFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3, validate_enabled=True)

try:  # container may not ship hypothesis; the seeded fallback covers it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _pool(seed, S, idle=False):
    rs = np.random.RandomState(seed)
    pool = {
        "mpki": (np.full((S,), 0.5, np.float32) if idle
                 else rs.uniform(1.0, 40.0, S).astype(np.float32)),
        "inst_per_miss": rs.uniform(30.0, 300.0, S).astype(np.float32),
        "rbl": rs.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rs.randint(1, 5, S).astype(np.int32),
        "is_gpu": np.zeros((S,), bool),
        "dl_period": np.zeros((S,), np.int32),
        "dl_reqs": np.zeros((S,), np.int32),
        "dl_jitter": np.zeros((S,), np.int32),
    }
    if not idle:
        pool["is_gpu"][-1] = True
    pool["dl_period"][0] = int(rs.randint(200, 600))
    pool["dl_reqs"][0] = int(rs.randint(5, 40))
    return pool


def _nonzero(dram):
    return {k: v for k, v in
            validate.summarize(np.asarray(dram["viol"])).items() if v}


def _stackable():
    return [n for n in sim.ALL_POLICIES
            if policy_api.is_stackable(n, CFG)]


# ---------------------------------------------------------------------------
# zero violations on healthy runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_name", sim.ALL_POLICIES)
@pytest.mark.parametrize("skip", [False, True], ids=["tick", "skip"])
def test_zero_violations_every_policy(policy_name, skip):
    assert CFG.energy_enabled and CFG.qos_enabled
    st_f = sim.simulate_debug(CFG, policy_name, _pool(7, CFG.n_src),
                              np.ones(CFG.n_src, bool), n_cycles=900,
                              skip=skip)
    assert not _nonzero(st_f[2]), (policy_name, skip)


@pytest.mark.parametrize("skip", [False, True], ids=["tick", "skip"])
def test_zero_violations_stacked(skip):
    out = sim.simulate_debug_stacked(CFG, _stackable(), _pool(7, CFG.n_src),
                                     np.ones(CFG.n_src, bool), n_cycles=900,
                                     skip=skip)
    for pol, (_, _, dram) in out.items():
        assert not _nonzero(dram), (pol, skip)


@pytest.mark.parametrize("policy_name,overrides", [
    ("parbs", dict(parbs_cap=2)),
    ("atlas", dict(atlas_epoch=96, cpu_reserve=1)),
    ("tcm", dict(tcm_quantum=64)),
    ("bliss", dict(bliss_clear_interval=500)),
    ("sms", dict(fifo_size=3, dcs_size=2)),
    ("squash_prio", dict(squash_epoch=128)),
])
@pytest.mark.parametrize("skip", [False, True], ids=["tick", "skip"])
def test_zero_violations_nondefault_knob_points(policy_name, overrides,
                                                skip):
    """Value and period knobs alike are plain SimConfig fields on the solo
    debug path, so non-default points exercise the same sanitizer."""
    cfg = CFG.replace(**overrides)
    st_f = sim.simulate_debug(cfg, policy_name, _pool(11, cfg.n_src),
                              np.ones(cfg.n_src, bool), n_cycles=900,
                              skip=skip)
    assert not _nonzero(st_f[2]), (policy_name, overrides, skip)


# ---------------------------------------------------------------------------
# the property, over randomized pools/configs/drivers
# ---------------------------------------------------------------------------

def _holds_for(seed):
    rs = np.random.RandomState(seed)
    cfg = CFG.replace(
        n_cpu=int(rs.randint(2, 5)),
        n_channels=int(rs.choice([1, 2])),
        buf_entries=int(rs.randint(8, 32)),
        parbs_cap=int(rs.randint(1, 6)),
        batch_age_cap=int(rs.randint(100, 2000)),
    )
    policy_name = sim.ALL_POLICIES[int(rs.randint(len(sim.ALL_POLICIES)))]
    skip = bool(rs.randint(2))
    pool = _pool(seed, cfg.n_src, idle=bool(rs.randint(2)))
    active = rs.rand(cfg.n_src) < 0.9
    active[0] = True
    st_f = sim.simulate_debug(cfg, policy_name, pool, active, n_cycles=500,
                              skip=skip)
    assert not _nonzero(st_f[2]), (seed, policy_name, skip)


@pytest.mark.parametrize("seed", range(6))
def test_property_no_violations_random_points(seed):
    _holds_for(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_property_no_violations_hypothesis(seed):
        _holds_for(seed)


# ---------------------------------------------------------------------------
# falsifiability: every fault class is caught
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fault", sorted(faults.FAULTS))
def test_fault_injection_flips_targeted_counter(fault):
    targets = faults.TARGETS[fault]
    skip = fault in faults.SKIP_ONLY
    # skip-machinery faults need spans to form: idle-heavy pool
    pool = _pool(7, CFG.n_src, idle=skip)
    active = np.ones(CFG.n_src, bool)
    with faults.inject(fault):
        if fault in faults.STACKED_ONLY:
            out = sim.simulate_debug_stacked(CFG, ("frfcfs", "parbs"), pool,
                                             active, n_cycles=800,
                                             skip=False)
            summary = validate.summarize(np.asarray(out["parbs"][2]["viol"]))
        else:
            st_f = sim.simulate_debug(CFG, "frfcfs", pool, active,
                                      n_cycles=800, skip=skip)
            summary = validate.summarize(np.asarray(st_f[2]["viol"]))
    assert sum(summary[k] for k in targets) > 0, (fault, summary)


def test_fault_injection_restores_cleanly():
    """Leaving the `inject` context unwinds the patch: the same run is
    violation-free again (and the PAR-BS write-set declaration is back)."""
    pool = _pool(7, CFG.n_src)
    active = np.ones(CFG.n_src, bool)
    with faults.inject("stacked_writeset"):
        pass
    parbs = policy_api.get("parbs")
    assert "msub" in parbs.stacked_tick_keys
    assert "msub" in parbs.stacked_issue_keys
    with faults.inject("dropped_completion"):
        pass
    st_f = sim.simulate_debug(CFG, "frfcfs", pool, active, n_cycles=400)
    assert not _nonzero(st_f[2])


def test_debug_check_clean_and_hard_fail():
    """`validate.debug_check` (checkify mode) passes a healthy run and
    raises — naming the first offending cycle — under a fault."""
    pool = _pool(7, CFG.n_src)
    active = np.ones(CFG.n_src, bool)
    st_f = validate.debug_check(CFG.replace(validate_enabled=False),
                                "frfcfs", pool, active, n_cycles=400)
    assert not np.asarray(st_f[2]["viol"]).any()
    with faults.inject("dropped_completion"):
        with pytest.raises(Exception, match="invariant violation at cycle"):
            validate.debug_check(CFG, "frfcfs", pool, active, n_cycles=400)


def test_unknown_fault_rejected():
    with pytest.raises(KeyError, match="unknown fault"):
        faults.inject("nope")


# ---------------------------------------------------------------------------
# prepare_pool input validation (named-column ValueErrors)
# ---------------------------------------------------------------------------

def test_prepare_pool_rejects_negative_deadline_period():
    pool = _pool(7, CFG.n_src)
    pool["dl_period"][1] = -5
    with pytest.raises(ValueError, match="dl_period.*negative"):
        sim.prepare_pool(pool, (CFG.n_src,))


def test_prepare_pool_rejects_out_of_range_src_class():
    pool = _pool(7, CFG.n_src)
    pool["src_class"] = np.full((CFG.n_src,), N_CLASSES, np.int32)
    with pytest.raises(ValueError, match="src_class.*CLASS_NAMES"):
        sim.prepare_pool(pool, (CFG.n_src,))


def test_prepare_pool_rejects_shape_mismatch():
    pool = _pool(7, CFG.n_src)
    pool["mpki"] = pool["mpki"][:-1]
    with pytest.raises(ValueError, match="mpki.*does not match"):
        sim.prepare_pool(pool, (CFG.n_src,))


def test_prepare_pool_rejects_wrong_dtypes():
    pool = _pool(7, CFG.n_src)
    pool["is_gpu"] = pool["is_gpu"].astype(np.int32)
    with pytest.raises(ValueError, match="is_gpu.*not bool"):
        sim.prepare_pool(pool, (CFG.n_src,))
    pool = _pool(7, CFG.n_src)
    pool["blp"] = pool["blp"].astype(np.float32)
    with pytest.raises(ValueError, match="blp.*not integral"):
        sim.prepare_pool(pool, (CFG.n_src,))


def test_prepare_pool_accepts_healthy_pool():
    out = sim.prepare_pool(_pool(7, CFG.n_src), (CFG.n_src,))
    assert "src_class" in out and "dl_jitter" in out
