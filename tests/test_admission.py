"""Utilization-aware admission control (repro.serving.admission): threshold
gating, moving-average spike detection, cooldown, conservation, registry."""
from repro.serving.admission import AdmissionControlScheduler, request_cost
from repro.serving.scheduler import SCHEDULERS
from repro.serving.types import Request


def _req(rid, cost, arrival=0.0, client=0):
    return Request(rid=rid, client=client, prefix_id=0, prompt_len=cost,
                   max_new=0, arrival=arrival)


def _mk(**kw):
    kw.setdefault("capacity_tokens", 1000)
    kw.setdefault("cooldown_ms", 25.0)
    return AdmissionControlScheduler(n_clients=4, **kw)


def test_registered_with_serving_registry():
    assert "admission" in SCHEDULERS
    sched = SCHEDULERS["admission"](4)
    assert isinstance(sched, AdmissionControlScheduler)


def test_admits_lightest_first_under_low_load():
    s = _mk()
    s.enqueue(_req(0, 300, arrival=0.0), 0.0)
    s.enqueue(_req(1, 100, arrival=1.0), 1.0)
    s.enqueue(_req(2, 100, arrival=0.5), 1.0)
    order = [s.pop_admission(2.0).rid for _ in range(3)]
    # lightest first; equal-cost ties broken by arrival (FCFS)
    assert order == [2, 1, 0]
    assert s.pop_admission(3.0) is None


def test_threshold_gates_admission():
    s = _mk(threshold=0.85, headroom=1.0)
    for rid in range(5):
        s.enqueue(_req(rid, 300), 0.0)
    admitted = []
    while (r := s.pop_admission(0.0)) is not None:
        admitted.append(r)
    # 2 x 300 in flight; a third would put effective load at 0.9 > 0.85
    assert len(admitted) == 2
    assert s.inflight_tokens == 600
    assert s.queued() == 3


def test_finish_frees_capacity_and_resumes():
    s = _mk(threshold=0.85, headroom=1.0)
    for rid in range(5):
        s.enqueue(_req(rid, 300), 0.0)
    a = s.pop_admission(0.0)
    b = s.pop_admission(0.0)
    assert s.pop_admission(0.0) is None
    s.on_finish(a)
    # cooldown may have latched on the step up to 0.6 utilization; admission
    # must resume once it expires
    assert s.pop_admission(s.cooldown_until + 1.0) is not None
    s.on_finish(b)
    assert s.inflight_tokens == 300


def test_spike_triggers_cooldown_then_recovers():
    s = _mk(threshold=0.9, headroom=1.0, ema_alpha=0.1, spike_ratio=1.5)
    # a long quiet phase anchors the moving average near zero load
    for t in range(50):
        s.pop_admission(float(t))
    assert s.spikes == 0
    # burst: one heavy admission jumps utilization far above the average
    s.enqueue(_req(0, 600), 50.0)
    heavy = s.pop_admission(50.0)
    assert heavy is not None
    assert s.pop_admission(50.5) is None   # queue empty; spike latches here
    assert s.spikes == 1
    s.enqueue(_req(1, 100), 51.0)
    assert s.pop_admission(51.0) is None, "admission during cooldown"
    assert s.cooldown_until > 51.0
    # load drained and cooldown expired: the light request is admitted
    s.on_finish(heavy)
    assert s.pop_admission(s.cooldown_until + 1.0).rid == 1


def test_gradual_rise_is_not_a_spike():
    s = _mk(threshold=0.95, headroom=1.0, ema_alpha=0.5, spike_ratio=1.5)
    # many light admissions, tracker stepping between each: the average
    # tracks the rise, so no spike/cooldown ever latches
    for rid in range(8):
        s.enqueue(_req(rid, 100), float(rid))
        assert s.pop_admission(float(rid)) is not None
    assert s.spikes == 0


def test_conservation_everything_eventually_admitted():
    s = _mk(threshold=0.85, headroom=1.0)
    reqs = [_req(rid, 150 + 37 * (rid % 5)) for rid in range(40)]
    for r in reqs:
        s.enqueue(r, 0.0)
    admitted, inflight, now = [], [], 0.0
    while len(admitted) < len(reqs):
        r = s.pop_admission(now)
        if r is not None:
            admitted.append(r)
            inflight.append(r)
        elif inflight:
            s.on_finish(inflight.pop(0))
        now += 1.0
        assert now < 10_000, "admission control wedged"
    assert sorted(r.rid for r in admitted) == [r.rid for r in reqs]
    for r in inflight:
        s.on_finish(r)
    assert s.inflight_tokens == 0 and s.queued() == 0


def test_cost_estimate_counts_decode_budget():
    assert request_cost(_req(0, 100)) == 100
    r = Request(rid=1, client=0, prefix_id=0, prompt_len=100, max_new=32,
                arrival=0.0)
    assert request_cost(r) == 132


def test_runs_under_serving_engine():
    from repro.serving.engine import EngineConfig, fairness_report
    from repro.serving.types import default_clients
    rep = fairness_report("admission", default_clients(), horizon_ms=1_000,
                          engine_cfg=EngineConfig())
    assert rep["total_finished"] > 0
