"""Hot-loop structural invariants for the per-cycle step.

The perf contract of the cond-gated scheduler refactor, checked at the
jaxpr level so a regression fails loudly instead of silently re-inflating
the trace:

  * sort primitives (argsort ranking, remark sorts) may appear ONLY inside
    `cond` branches of the per-cycle step for every centralized policy —
    never unconditionally;
  * the ranked policies (atlas/tcm) actually HAVE their sorts behind a
    cond (the check isn't vacuous), while PAR-BS — reformulated to the
    amortized pairwise-rank form — has no sort primitive at all;
  * the scan carry holds only cycle-varying state: the read-only workload
    parameters `_pool`/`_active` are closed over, not carried;
  * the refactor is bit-identical: the golden digests for atlas/parbs/tcm
    (captured pre-refactor) still match.
"""
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import energy, engine, params, qos, validate
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core.params import Knobs, SimConfig
from repro.core.schedulers import CentralizedPolicy

CFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3)

SORT_PRIMS = {"sort"}


def _centralized_names():
    return [n for n in policy_api.names()
            if isinstance(policy_api.get(n), CentralizedPolicy)]


def _dummy_pool(cfg):
    S = cfg.n_src
    pool = {k: jnp.zeros((S,), jnp.float32)
            for k in ("mpki", "inst_per_miss", "rbl")}
    pool.update(blp=jnp.ones((S,), jnp.int32),
                is_gpu=jnp.zeros((S,), bool))
    return sim.prepare_pool(pool, (S,))


# jaxpr-walking helpers live in repro.compat (the Jaxpr/ClosedJaxpr types
# moved out of jax.core; compat resolves the right location per jax version)
_walk_prims = compat.walk_primitives


def _step_jaxpr(policy_name, base_cfg=CFG):
    cfg, pol, carry = sim._init(base_cfg, policy_name)
    pool = _dummy_pool(cfg)
    active = jnp.ones((cfg.n_src,), bool)
    step = policy_api.make_step(cfg, pol, pool, active)
    return jax.make_jaxpr(step)(carry, jnp.int32(5))


@pytest.mark.parametrize("policy_name", _centralized_names())
def test_no_unconditional_sorts_in_step(policy_name):
    """Per-cycle jaxpr: sort ops only inside cond branches."""
    jx = _step_jaxpr(policy_name)
    uncond = [p for p, in_cond in _walk_prims(jx.jaxpr)
              if p in SORT_PRIMS and not in_cond]
    assert not uncond, (
        f"{policy_name}: {len(uncond)} unconditional sort op(s) in the "
        f"per-cycle step — ranking belongs in boundary_tick behind cond")


@pytest.mark.parametrize("policy_name", ["atlas", "tcm"])
def test_ranked_policies_sort_inside_cond(policy_name):
    """Non-vacuity: the ranked policies do sort, behind the boundary cond."""
    jx = _step_jaxpr(policy_name)
    gated = [p for p, in_cond in _walk_prims(jx.jaxpr)
             if p in SORT_PRIMS and in_cond]
    assert gated, f"{policy_name}: expected ranking sorts inside cond"


def test_parbs_step_is_sort_free():
    """PAR-BS batch-boundary residue fix: the amortized-rank form computes
    source priority by pairwise comparison counts, so its step jaxpr has NO
    sort primitive at all — gated or not — and no data-dependent cond is
    left on the stacked path for it."""
    jx = _step_jaxpr("parbs")
    sorts = [p for p, _ in _walk_prims(jx.jaxpr) if p in SORT_PRIMS]
    assert not sorts, f"parbs: {len(sorts)} sort op(s) — residue regressed"


def test_energy_accounting_adds_no_sorts_or_scatters():
    """repro.core.energy rides the per-cycle hot loop: enabling it must add
    zero sort/scatter/gather primitives to the step jaxpr (hot-loop rules
    1 + 3 — the counters are elementwise/one-hot-masked updates only)."""
    assert CFG.energy_enabled

    def counts(jx):
        out = {}
        for p, _ in _walk_prims(jx.jaxpr):
            fam = next((f for f in ("sort", "scatter", "gather")
                        if p.startswith(f)), None)
            if fam:
                out[fam] = out.get(fam, 0) + 1
        return out

    off_cfg = CFG.replace(energy_enabled=False)
    for name in ("frfcfs", "atlas", "sms"):
        on, off = counts(_step_jaxpr(name)), counts(_step_jaxpr(name, off_cfg))
        assert on == off, (
            f"{name}: energy accounting changed sort/scatter/gather "
            f"population: {off} -> {on}")


def test_qos_accounting_adds_no_sorts_or_scatters():
    """Same hot-loop contract for repro.core.qos: the latency histogram is
    a one-hot masked accumulation, so enabling it must add zero
    sort/scatter/gather primitives to the step jaxpr."""
    assert CFG.qos_enabled

    def counts(jx):
        out = {}
        for p, _ in _walk_prims(jx.jaxpr):
            fam = next((f for f in ("sort", "scatter", "gather")
                        if p.startswith(f)), None)
            if fam:
                out[fam] = out.get(fam, 0) + 1
        return out

    off_cfg = CFG.replace(qos_enabled=False)
    for name in ("frfcfs", "atlas", "sms"):
        on, off = counts(_step_jaxpr(name)), counts(_step_jaxpr(name, off_cfg))
        assert on == off, (
            f"{name}: QoS accounting changed sort/scatter/gather "
            f"population: {off} -> {on}")


def test_validate_off_adds_zero_primitives(monkeypatch):
    """The sanitizer is gated at TRACE time: with `validate_enabled=False`
    (the default) none of its counter functions may even be called during
    tracing, so the per-cycle jaxpr is untouched — zero primitives added,
    not merely zero sorts. Proven by poisoning every validate entry point
    and tracing both drivers."""
    assert not CFG.validate_enabled

    def boom(*a, **k):
        raise AssertionError("validate code reached with validate off")

    for fn in ("issue_counts", "tick_counts", "span_counts"):
        monkeypatch.setattr(validate, fn, boom)
    for name in ("frfcfs", "parbs", "sms"):
        cfg, pol, carry = sim._init(CFG, name)
        pool = _dummy_pool(cfg)
        active = jnp.ones((cfg.n_src,), bool)
        jax.make_jaxpr(policy_api.make_step(cfg, pol, pool, active))(
            carry, jnp.int32(5))
        body = policy_api.make_skip_step(cfg, pol, pool, active)
        jax.make_jaxpr(body)(carry, jnp.int32(5), jnp.int32(100))
    # non-vacuity: the same poison DOES fire once the sanitizer is on
    cfg, pol, carry = sim._init(CFG.replace(validate_enabled=True), "frfcfs")
    with pytest.raises(AssertionError, match="validate code reached"):
        jax.make_jaxpr(policy_api.make_step(
            cfg, pol, _dummy_pool(cfg),
            jnp.ones((cfg.n_src,), bool)))(carry, jnp.int32(5))


def _step_jaxpr_traced_knobs(policy_name, base_cfg=CFG):
    """Per-cycle step with the knob point as a TRACED argument (the batched
    design-grid path) instead of baked constants."""
    bound, pol, carry = sim._init(base_cfg, policy_name)
    pool = _dummy_pool(bound)
    active = jnp.ones((bound.n_src,), bool)
    base = bound.base

    def step(carry, t, kn):
        return policy_api.make_step(params.bind(base, kn), pol, pool,
                                    active)(carry, t)

    return jax.make_jaxpr(step)(carry, jnp.int32(5), Knobs.from_cfg(base))


def _prim_counts(jx):
    out = {}
    for p, _ in _walk_prims(jx.jaxpr):
        fam = next((f for f in ("sort", "scatter", "gather")
                    if p.startswith(f)), None)
        if fam:
            out[fam] = out.get(fam, 0) + 1
    return out


@pytest.mark.parametrize("policy_name", ["frfcfs", "atlas", "parbs", "sms"])
def test_knob_batching_adds_no_sorts_or_scatters(policy_name):
    """Lifting knobs from baked trace constants to traced arrays (the
    one-program design grid) must add ZERO sort/scatter/gather primitives
    to the per-cycle jaxpr — knob reads are elementwise operands, never
    indexing or ranking work."""
    baked = _prim_counts(_step_jaxpr(policy_name))
    traced = _prim_counts(_step_jaxpr_traced_knobs(policy_name))
    assert traced == baked, (
        f"{policy_name}: traced knobs changed sort/scatter/gather "
        f"population: {baked} -> {traced}")


@pytest.mark.parametrize("policy_name", ["atlas", "tcm"])
def test_traced_knobs_keep_sorts_cond_gated(policy_name):
    """The t-only boundary conds survive knob tracing: ranking sorts stay
    behind cond in the traced-knob jaxpr (period knobs are per-slice static,
    so the predicate stays unbatched)."""
    jx = _step_jaxpr_traced_knobs(policy_name)
    uncond = [p for p, in_cond in _walk_prims(jx.jaxpr)
              if p in SORT_PRIMS and not in_cond]
    assert not uncond, (
        f"{policy_name}: knob tracing un-gated {len(uncond)} sort op(s)")


def test_simspeed_bench_recorded_speedup_holds():
    """House gate on the recorded benchmark file: the sweep throughput
    captured in BENCH_simspeed.json must hold the hot-loop optimization win
    over the pre-optimization baseline. Refresh with `make bench-simspeed`
    after hot-loop signature changes — a refreshed "current" that falls
    under the gate means a real cycles/sec regression."""
    path = Path(__file__).parents[1] / "BENCH_simspeed.json"
    data = json.loads(path.read_text())
    ratio = data.get("sweep_speedup_vs_baseline_x")
    assert ratio is not None, \
        "BENCH_simspeed.json is missing the sweep speedup — run " \
        "`make bench-simspeed` to remeasure"
    assert ratio >= 2.0, (
        f"recorded sweep speedup {ratio:.2f}x < 2x baseline — the hot loop "
        f"regressed (or the BENCH file needs a remeasure on faster hardware)")


def test_scan_carry_has_no_pool_or_active():
    """The carry pytree holds only cycle-varying state."""
    for name in sim.ALL_POLICIES:
        _, _, (st, sched, dram) = sim._init(CFG, name)
        for tree in (st, sched, dram):
            assert "_pool" not in tree and "_active" not in tree, name
        assert not any(k.startswith("_") for k in st), \
            f"{name}: non-state key smuggled into the carry: {sorted(st)}"


# ---------------------------------------------------------------------------
# bit-identity re-check for the cond refactor (same protocol as
# test_policy_registry, focused on the three re-ranked policies)
# ---------------------------------------------------------------------------

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_policy_states.json").read_text())


def _golden_pool(cfg):
    rng = np.random.RandomState(42)
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
        "dl_period": np.zeros(S, np.int32),
        "dl_reqs": np.zeros(S, np.int32),
    }
    pool["dl_period"][0] = 400
    pool["dl_reqs"][0] = 35
    return pool


def _digest(tree):
    out = {}
    for key in sorted(tree):
        if key.startswith("_"):
            continue
        v = np.ascontiguousarray(tree[key])
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
        out[key] = h.hexdigest()
    return out


@pytest.mark.parametrize("policy_name", ["atlas", "parbs", "tcm"])
def test_cond_refactor_bit_identical(policy_name):
    # runs with the energy subsystem ON (CFG default): the goldens predate
    # it, so matching them on every non-energy key proves energy accounting
    # is purely additive to the scheduling decisions
    st_f, sched_f, dram_f = sim.simulate_debug(
        CFG, policy_name, _golden_pool(CFG), np.ones(CFG.n_src, bool),
        n_cycles=1_500)
    g = GOLDEN[policy_name]
    for part, tree in (("src", st_f), ("dram", dram_f)):
        new = _digest(tree)
        extra = set(new) - set(g[part])
        allowed = set(energy.STATE_KEYS) | set(qos.STATE_KEYS) \
            if part == "dram" else set(engine.NCLASS_SRC_KEYS)
        assert extra <= allowed, \
            f"{policy_name} {part} grew unexpected keys: {extra}"
        for k, h in g[part].items():
            assert new[k] == h, f"{policy_name} {part}[{k}] diverged"
    sched = _digest(sched_f)
    for k in set(sched) & set(g["sched"]):
        assert sched[k] == g["sched"][k], f"{policy_name} sched[{k}] diverged"


@pytest.mark.parametrize("policy_name", ["atlas", "parbs", "tcm"])
def test_validate_on_bit_identical(policy_name):
    """Flipping the sanitizer ON is measurement-only: every golden digest
    still matches bit-for-bit (the counters never feed back into a
    scheduling decision), the only new dram key is the violation vector,
    and that vector is all zeros on a healthy run."""
    st_f, sched_f, dram_f = sim.simulate_debug(
        CFG.replace(validate_enabled=True), policy_name, _golden_pool(CFG),
        np.ones(CFG.n_src, bool), n_cycles=1_500)
    assert not np.asarray(dram_f["viol"]).any(), \
        validate.summarize(np.asarray(dram_f["viol"]))
    g = GOLDEN[policy_name]
    for part, tree in (("src", st_f), ("dram", dram_f)):
        new = _digest(tree)
        extra = set(new) - set(g[part])
        allowed = set(energy.STATE_KEYS) | set(qos.STATE_KEYS) \
            | set(validate.STATE_KEYS) if part == "dram" \
            else set(engine.NCLASS_SRC_KEYS)
        assert extra <= allowed, \
            f"{policy_name} {part} grew unexpected keys: {extra}"
        for k, h in g[part].items():
            assert new[k] == h, \
                f"{policy_name} {part}[{k}] diverged under validate"
    sched = _digest(sched_f)
    for k in set(sched) & set(g["sched"]):
        assert sched[k] == g["sched"][k], \
            f"{policy_name} sched[{k}] diverged under validate"
