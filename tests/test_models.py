"""Per-arch smoke tests (reduced configs): one train step on CPU, output
shapes, no NaNs; prefill+decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig, reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.models import lm as lm_lib
from repro.models.registry import get_model, input_specs

RUN = RunConfig(compute_dtype="float32", remat="none")
RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_loss(arch):
    cfg = reduced(get_config(arch))
    bundle = get_model(cfg)
    params = bundle.init(RNG)
    loss = bundle.train_loss(params, RUN, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    # loss near ln(V) at init (sane distribution head)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_updates(arch):
    """One full optimizer step changes params and returns finite grads."""
    from repro.optim import adamw
    cfg = reduced(get_config(arch), n_layers=2)
    bundle = get_model(cfg)
    params = bundle.init(RNG)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: bundle.train_loss(p, RUN, batch))(params)
    gnorm = adamw.global_norm(grads)
    assert jnp.isfinite(gnorm) and float(gnorm) > 0
    opt = adamw.init(params)
    new_params, _ = adamw.update(grads, opt, params, lr=1e-3)
    diff = adamw.global_norm(jax.tree_util.tree_map(
        lambda a, b: a - b, new_params, params))
    assert float(diff) > 0


@pytest.mark.parametrize("arch", ["gemma2-2b", "moonshot-v1-16b-a3b",
                                  "hymba-1.5b", "xlstm-125m",
                                  "whisper-large-v3", "command-r-plus-104b"])
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    bundle = get_model(cfg)
    params = bundle.init(RNG)
    B, S = 2, 16
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    extra = None
    if cfg.family == "audio":
        ae = jax.random.normal(RNG, (B, cfg.encoder_seq, cfg.d_model))
        batch["audio_embeds"] = ae
        extra = {"audio_embeds": ae}
    if cfg.family == "ssm":
        from repro.models import xlstm as m
        full, _ = m.forward_train(params, cfg, RUN, batch)
    elif cfg.family == "hybrid":
        from repro.models import hymba as m
        full, _ = m.forward_train(params, cfg, RUN, batch)
    elif cfg.family == "audio":
        from repro.models import whisper as m
        full, _ = m.forward_train(params, cfg, RUN, batch)
    else:
        full, _ = lm_lib.forward_train(params, cfg, RUN, batch)
    cache = bundle.init_cache(B, S, dtype=jnp.float32) \
        if cfg.family != "ssm" else None
    lg_pre, cache2, lens = bundle.prefill(params, RUN, cache,
                                          toks[:, :S - 1], extra=extra)
    lg_dec, _ = bundle.decode_step(params, RUN, cache2, toks[:, S - 1], lens)
    np.testing.assert_allclose(lg_pre, full[:, S - 2], atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(lg_dec, full[:, S - 1], atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_cells(arch):
    from repro.configs.base import SHAPES, shape_cells
    cfg = get_config(arch)
    for cell in shape_cells(arch):
        specs = input_specs(cfg, SHAPES[cell])
        assert specs, f"{arch} x {cell}: empty specs"
        leaves = jax.tree_util.tree_leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_mlstm_chunk_invariance():
    """Chunkwise mLSTM must be invariant to the chunk size."""
    from repro.models.xlstm import mlstm_chunk_scan
    rng = np.random.RandomState(1)
    B, H, S, dh = 2, 2, 48, 8
    q, k, v = (jnp.asarray(rng.randn(B, H, S, dh), jnp.float32)
               for _ in range(3))
    lf = jnp.asarray(np.log(rng.uniform(0.6, 0.99, (B, H, S))), jnp.float32)
    li = jnp.asarray(rng.randn(B, H, S) * 0.5, jnp.float32)
    s0 = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
          jnp.full((B, H), -40.0))
    h1, st1 = mlstm_chunk_scan(q, k, v, lf, li, s0, chunk=48)
    h2, st2 = mlstm_chunk_scan(q, k, v, lf, li, s0, chunk=8)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st1[0], st2[0], atol=1e-4, rtol=1e-3)


def test_selective_scan_matches_naive():
    from repro.models.ssm import selective_scan
    rng = np.random.RandomState(2)
    B, S, di, N = 2, 40, 6, 4
    u = jnp.asarray(rng.randn(B, S, di), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 1.0, (di, N)), jnp.float32)
    Bc = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cc = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    h0 = jnp.zeros((B, di, N))
    y, hf = selective_scan(u, dt, A, Bc, Cc, h0, chunk=8)
    # naive recurrence
    h = np.zeros((B, di, N))
    ys = []
    for t in range(S):
        da = np.exp(np.asarray(dt[:, t])[..., None] * np.asarray(A))
        h = da * h + (np.asarray(dt[:, t]) * np.asarray(u[:, t]))[..., None] \
            * np.asarray(Bc[:, t])[:, None, :]
        ys.append(np.einsum("bdn,bn->bd", h, np.asarray(Cc[:, t])))
    np.testing.assert_allclose(y, np.stack(ys, 1), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(hf, h, atol=1e-4, rtol=1e-3)


def test_moe_shard_map_matches_einsum_reference():
    """EP shard_map path == one-hot einsum reference (1-device mesh)."""
    from repro.configs.base import reduced
    from repro.models import moe as moe_lib
    cfg = reduced(get_config("moonshot-v1-16b-a3b"))
    rng = np.random.RandomState(3)
    p = {k: jnp.asarray(rng.randn(*d.shape) * 0.05, jnp.float32)
         for k, d in moe_lib.moe_defs(cfg).items()}
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model), jnp.float32)
    y1, aux1 = moe_lib.moe_apply(x, p, cfg, RUN, mesh=None)
    y2, aux2 = moe_lib.moe_apply_einsum(x, p, cfg)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(aux1, aux2, atol=1e-5, rtol=1e-4)
