"""Serving engine: allocator properties (hypothesis), scheduler fairness
orderings, request conservation, paged-LM equivalence with dense decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep; pip install -r "
                    "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.configs.base import RunConfig, reduced
from repro.configs.registry import get_config
from repro.serving.engine import EngineConfig, fairness_report, run_serving
from repro.serving.kv_cache import PagedAllocator
from repro.serving.types import ClientSpec, default_clients


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(st.lists(st.tuples(st.integers(1, 200), st.booleans(),
                          st.integers(0, 2)), min_size=1, max_size=40),
       st.integers(1, 1 << 30))
def test_allocator_never_double_allocates(ops, seed):
    rng = np.random.RandomState(seed % (2**32))
    alloc = PagedAllocator(n_pages=64, page_size=16)
    live = []
    for total_len, use_prefix, pfx in ops:
        if live and rng.rand() < 0.4:
            pages, _ = live.pop(rng.randint(len(live)))
            alloc.free_seq(pages)
            continue
        got = alloc.alloc_seq(total_len, pfx if use_prefix else None,
                              prefix_len=min(total_len, 48))
        if got is not None:
            live.append(got)
        # invariant: page is free XOR refcounted
        free = set(alloc.free)
        assert len(free) == len(alloc.free), "duplicate in free list"
        for p in range(alloc.n_pages):
            if p in free:
                assert alloc.refcount[p] == 0
            else:
                assert alloc.refcount[p] > 0
    # full cleanup releases all private pages
    for pages, _ in live:
        alloc.free_seq(pages)
    for pfx, pages in alloc.prefix_pages.items():
        for p in pages:
            alloc.unref(p)
    assert alloc.n_free == alloc.n_pages


def test_prefix_pages_are_shared():
    alloc = PagedAllocator(n_pages=32, page_size=16)
    a, na = alloc.alloc_seq(64, prefix_id=7, prefix_len=32)
    b, nb = alloc.alloc_seq(64, prefix_id=7, prefix_len=32)
    assert na == nb == 2
    assert a[:2] == b[:2], "shared prefix must reuse pages"
    assert set(a[2:]).isdisjoint(b[2:]), "private tails must not alias"


# ---------------------------------------------------------------------------
# scheduler / engine behaviour
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_results():
    clients = default_clients()
    return {p: fairness_report(p, clients, horizon_ms=2_500,
                               engine_cfg=EngineConfig())
            for p in ("fcfs", "locality", "sms")}


def test_sms_serving_fairness_beats_baselines(serving_results):
    r = serving_results
    assert r["sms"]["max_slowdown"] < r["fcfs"]["max_slowdown"]
    assert r["sms"]["max_slowdown"] < r["locality"]["max_slowdown"]


def test_sms_throughput_within_10pct(serving_results):
    r = serving_results
    assert r["sms"]["total_tok_s"] > 0.9 * r["locality"]["total_tok_s"]


def test_all_requests_complete(serving_results):
    counts = {p: r["total_finished"] for p, r in serving_results.items()}
    assert len(set(counts.values())) == 1, f"request loss: {counts}"


def test_bulk_not_starved(serving_results):
    """RR share (1-p) must keep the bulk tenant progressing under SMS."""
    sd = serving_results["sms"]["slowdowns"]
    assert sd.get("bulk", 99.0) < 3.0


def test_adaptive_p_controller():
    """Adaptive p converges to a good operating point from a poor start and
    beats a badly fixed p on fairness (beyond-paper: §5 p-study automated)."""
    from repro.serving.scheduler import SMSScheduler
    clients = default_clients()
    adaptive = fairness_report("sms_adaptive", clients, horizon_ms=2_500,
                               engine_cfg=EngineConfig())
    # fixed p = 0.5 (too much round-robin for this mix)
    import repro.serving.scheduler as sched_mod
    orig = sched_mod.SCHEDULERS["sms"]
    sched_mod.SCHEDULERS["sms"] = (
        lambda n, seed=0: SMSScheduler(n, sjf_prob=0.5, seed=seed))
    try:
        fixed_low = fairness_report("sms", clients, horizon_ms=2_500,
                                    engine_cfg=EngineConfig())
    finally:
        sched_mod.SCHEDULERS["sms"] = orig
    assert adaptive["max_slowdown"] <= fixed_low["max_slowdown"] * 1.05, \
        (adaptive["max_slowdown"], fixed_low["max_slowdown"])


# ---------------------------------------------------------------------------
# paged-LM equivalence
# ---------------------------------------------------------------------------

def test_paged_lm_matches_dense_decode():
    from repro.models.registry import get_model
    from repro.serving import paged_lm
    run = RunConfig(compute_dtype="float32")
    cfg = reduced(get_config("gemma2-2b"), n_layers=2)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S, page = 2, 10, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = bundle.init_cache(B, S, dtype=jnp.float32)
    lg_ref, cache, lens = bundle.prefill(params, run, cache, toks[:, :S - 1])
    lg_ref2, _ = bundle.decode_step(params, run, cache, toks[:, S - 1], lens)
    pools = paged_lm.init_pools(cfg, n_pages=12, page_size=page)
    pt = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    for t in range(S):
        lg, pools = paged_lm.paged_decode_step(
            params, cfg, run, pools, toks[:, t],
            jnp.full((B,), t, jnp.int32), pt, page_size=page)
        if t == S - 2:
            lg_pre = lg
    np.testing.assert_allclose(lg_pre, lg_ref, atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(lg, lg_ref2, atol=2e-4, rtol=2e-3)
