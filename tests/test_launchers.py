"""User-facing entry points: train/serve launchers + VLM serving path."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def _run(args, timeout=520):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


def test_train_launcher_smoke():
    out = _run(["repro.launch.train", "--arch", "qwen1.5-4b", "--smoke",
                "--steps", "4", "--seq", "64", "--batch", "4"])
    assert "[train] done at step 4" in out
    # loss printed and finite
    assert "loss" in out


def test_serve_launcher():
    out = _run(["repro.launch.serve", "--scheduler", "sms",
                "--horizon", "1500"])
    assert "max slowdown" in out
    assert "bulk" in out


def test_serve_launcher_adaptive():
    out = _run(["repro.launch.serve", "--scheduler", "sms_adaptive",
                "--horizon", "1200"])
    assert "max slowdown" in out


def test_llava_prefill_decode_consistency():
    """VLM: prefill with stub image embeddings + decode matches forward."""
    from repro.configs.base import RunConfig, reduced
    from repro.configs.registry import get_config
    from repro.models import lm as lm_lib
    from repro.models.registry import get_model
    run = RunConfig(compute_dtype="float32")
    cfg = reduced(get_config("llava-next-mistral-7b"))
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S_text = 2, 12
    n_img = cfg.n_image_tokens
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_text), 0,
                              cfg.vocab_size)
    img = jax.random.normal(jax.random.PRNGKey(2), (B, n_img, cfg.d_model))
    batch = {"tokens": toks, "labels": toks, "image_embeds": img}
    full, _ = lm_lib.forward_train(params, cfg, run, batch)
    # prefill over image+text prefix, decode the last text token
    total = n_img + S_text
    cache = bundle.init_cache(B, total, dtype=jnp.float32)
    lg_pre, cache2, lens = bundle.prefill(
        params, run, cache, toks[:, :S_text - 1],
        extra={"image_embeds": img})
    lg_dec, _ = bundle.decode_step(params, run, cache2, toks[:, S_text - 1],
                                   lens)
    np.testing.assert_allclose(lg_pre, full[:, total - 2], atol=2e-4,
                               rtol=2e-3)
    np.testing.assert_allclose(lg_dec, full[:, total - 1], atol=2e-4,
                               rtol=2e-3)
