"""Unit tests for `repro.compat` — the one-file jax version shim.

These exist so the next jax bump fails HERE, loudly and attributably,
instead of deep inside `moe.py`/`distributed.pipeline` at trace time:
shard_map resolution + check-kwarg translation, tree_map, the jaxpr
walkers the perf-invariant tests build on, and jit cache introspection.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import compat


# ---------------------------------------------------------------------------
# shard_map: resolution + kwarg translation
# ---------------------------------------------------------------------------

def test_resolve_shard_map_finds_an_impl():
    impl, kw = compat._resolve_shard_map()
    assert callable(impl)
    assert kw in (None, "check_rep", "check_vma"), kw
    # the module-level binding matches a fresh resolution
    assert compat._CHECK_KW == kw


@pytest.mark.parametrize("native_kw", ["check_rep", "check_vma"])
def test_shard_map_translates_check_kwarg(monkeypatch, native_kw):
    """Callers always pass the modern `check_vma`; the shim must hand the
    pinned implementation whatever spelling it natively accepts."""
    seen = {}

    def fake_impl(f, mesh, in_specs, out_specs, **kw):
        seen.update(kw, mesh=mesh)
        return "mapped"

    monkeypatch.setattr(compat, "_SHARD_MAP_IMPL", fake_impl)
    monkeypatch.setattr(compat, "_CHECK_KW", native_kw)
    out = compat.shard_map(lambda x: x, mesh="MESH", in_specs=(),
                           out_specs=(), check_vma=False)
    assert out == "mapped"
    assert seen[native_kw] is False and seen["mesh"] == "MESH"
    assert ("check_vma" in seen) == (native_kw == "check_vma")


def test_shard_map_no_check_kwarg_supported(monkeypatch):
    """An impl with no replication-check kwarg gets none injected."""
    seen = {}

    def fake_impl(f, mesh, in_specs, out_specs, **kw):
        seen.update(kw)
        return "mapped"

    monkeypatch.setattr(compat, "_SHARD_MAP_IMPL", fake_impl)
    monkeypatch.setattr(compat, "_CHECK_KW", None)
    compat.shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(),
                     check_vma=True)
    assert "check_vma" not in seen and "check_rep" not in seen


def test_shard_map_explicit_native_kwarg_wins(monkeypatch):
    """A caller passing the native kwarg directly is not second-guessed."""
    seen = {}

    def fake_impl(f, mesh, in_specs, out_specs, **kw):
        seen.update(kw)

    monkeypatch.setattr(compat, "_SHARD_MAP_IMPL", fake_impl)
    monkeypatch.setattr(compat, "_CHECK_KW", "check_rep")
    compat.shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(),
                     check_vma=True, check_rep=False)
    assert seen["check_rep"] is False


# ---------------------------------------------------------------------------
# tree_map
# ---------------------------------------------------------------------------

def test_tree_map_is_usable_and_non_deprecated_path():
    out = compat.tree_map(lambda a, b: a + b, {"x": 1, "y": (2, 3)},
                          {"x": 10, "y": (20, 30)})
    assert out == {"x": 11, "y": (22, 33)}


# ---------------------------------------------------------------------------
# jaxpr walkers (what test_perf_invariants / test_stacked_vmap build on)
# ---------------------------------------------------------------------------

def _cond_sort_fn(x):
    y = jnp.sort(x)                                  # unconditional sort
    return jax.lax.cond(y[0] > 0.0,
                        lambda v: jnp.sort(-v),      # sort inside cond
                        lambda v: v, y)


def test_walk_primitives_distinguishes_cond_branches():
    jx = jax.make_jaxpr(_cond_sort_fn)(jnp.arange(4.0))
    prims = list(compat.walk_primitives(jx.jaxpr))
    assert ("sort", False) in prims, "missed the unconditional sort"
    assert ("sort", True) in prims, "missed the cond-gated sort"
    # nesting flag is sticky: everything under the cond is flagged
    assert all(in_cond for p, in_cond in prims if p == "sort" and in_cond)


def test_walk_primitives_descends_into_scan_bodies():
    def scanned(x):
        return jax.lax.scan(lambda c, _: (jnp.sort(c), None), x,
                            jnp.arange(3))[0]
    jx = jax.make_jaxpr(scanned)(jnp.arange(4.0))
    assert ("sort", False) in compat.walk_primitives(jx.jaxpr)


def test_sub_jaxprs_unwraps_closed_lists_and_ignores_scalars():
    jx = jax.make_jaxpr(_cond_sort_fn)(jnp.arange(4.0))
    cond_eqn = next(e for e in jx.jaxpr.eqns if e.primitive.name == "cond")
    branches = cond_eqn.params["branches"]
    subs = compat.sub_jaxprs(branches)
    assert len(subs) == 2 and all(isinstance(j, compat.Jaxpr) for j in subs)
    assert compat.sub_jaxprs(jx) == [jx.jaxpr]   # ClosedJaxpr unwraps
    assert compat.sub_jaxprs(3) == []
    assert compat.sub_jaxprs([jx.jaxpr, (branches[0],)]) \
        == [jx.jaxpr, branches[0].jaxpr]


# ---------------------------------------------------------------------------
# jit cache introspection (bench-smoke's one-XLA-program gate)
# ---------------------------------------------------------------------------

def test_jit_cache_size_counts_distinct_programs():
    @jax.jit
    def g(x):
        return x * 2

    base = compat.jit_cache_size(g)
    g(jnp.zeros((2,)))
    g(jnp.zeros((3,)))                           # new shape -> new program
    g(jnp.zeros((3,)))                           # cache hit -> no new program
    assert compat.jit_cache_size(g) - base == 2
