"""N-class requester model: 2-class bit-identity through the N-class
engine, HWA frame-deadline accounting identities, stacked-path parity for
the new pool keys, and the measurement-only QoS contract."""
import jax
import numpy as np
import pytest

from repro.core import engine, metrics as met, policy, qos
from repro.core import simulator as sim
from repro.core.params import CLS_CPU, CLS_GPU, CLS_HWA, SimConfig

CFG2 = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=32,
                 fifo_size=5, dcs_size=3)
CFG3 = CFG2.replace(n_hwa=2)
CYCLES, WARMUP = 2_000, 500


def _legacy_pool():
    """2-class pool with only the legacy keys (no src_class/dl_jitter)."""
    mpki = np.array([25, 40, 18, 1000], np.float32)
    return {
        "mpki": mpki, "inst_per_miss": np.maximum(1000 / mpki, 1),
        "rbl": np.array([.5, .4, .6, .9], np.float32),
        "blp": np.array([3, 2, 4, 4], np.int32),
        "is_gpu": np.array([0, 0, 0, 1], bool),
    }


def _nclass_pool(jitter=(12, 0)):
    """3 CPUs + 1 GPU + 2 frame-deadline HWAs, full N-class schema."""
    mpki = np.array([25, 40, 18, 1000, 1000, 1000], np.float32)
    return {
        "mpki": mpki, "inst_per_miss": np.maximum(1000 / mpki, 1),
        "rbl": np.array([.5, .4, .6, .9, .85, .7], np.float32),
        "blp": np.array([3, 2, 4, 4, 2, 3], np.int32),
        "is_gpu": np.array([0, 0, 0, 1, 0, 0], bool),
        "src_class": np.array([CLS_CPU] * 3 + [CLS_GPU] + [CLS_HWA] * 2,
                              np.int32),
        "dl_period": np.array([0, 0, 0, 0, 500, 400], np.int32),
        "dl_reqs": np.array([0, 0, 0, 0, 25, 15], np.int32),
        "dl_jitter": np.array([0, 0, 0, 0, jitter[0], jitter[1]], np.int32),
    }


def _batch(pool):
    return {k: v[None] for k, v in pool.items()}


def _run(cfg, pol, pool, n_cycles=CYCLES, warmup=WARMUP):
    active = np.ones((1, cfg.n_src), bool)
    return sim.simulate(cfg, pol, _batch(pool), active, n_cycles, warmup)


def _expected_frames(period, warmup=WARMUP, n_cycles=CYCLES):
    return sum(1 for t in range(warmup, warmup + n_cycles)
               if t > 0 and t % period == 0)


# ---------------------------------------------------------------------------
# 2-class golden equivalence: the N-class engine is a strict superset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", policy.names())
def test_legacy_pool_bit_identical_to_explicit_classes(pol):
    """A legacy is_gpu pool and the same pool with the N-class keys spelled
    out (derived src_class, zero deadline stream) must be bit-identical —
    the schema completion in `prepare_pool` is the only difference."""
    legacy = _legacy_pool()
    explicit = dict(legacy)
    explicit["src_class"] = np.array([CLS_CPU, CLS_CPU, CLS_CPU, CLS_GPU],
                                     np.int32)
    for k in ("dl_period", "dl_reqs", "dl_jitter"):
        explicit[k] = np.zeros(CFG2.n_src, np.int32)
    a = _run(CFG2, pol, legacy)
    b = _run(CFG2, pol, explicit)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{pol}:{k}")


def test_derive_src_class_reproduces_legacy_partition():
    is_gpu = np.array([0, 1, 0, 0], bool)
    dlp = np.array([0, 0, 0, 700], np.int32)
    cls = np.asarray(engine.derive_src_class(is_gpu, dlp))
    np.testing.assert_array_equal(
        cls, [CLS_CPU, CLS_GPU, CLS_CPU, CLS_HWA])


# ---------------------------------------------------------------------------
# HWA frame-deadline accounting identities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", ("frfcfs", "sms_dash"))
def test_frame_accounting_identity(pol):
    """frames_released == dl_met + dl_missed, released count matches the
    period boundaries inside the measurement window, and non-deadline
    sources never release frames."""
    m = _run(CFG3, pol, _nclass_pool())
    rel = m["frames_released"][0]
    np.testing.assert_array_equal(rel, m["dl_met"][0] + m["dl_missed"][0],
                                  err_msg=pol)
    assert rel[4] == _expected_frames(500)
    assert rel[5] == _expected_frames(400)
    assert (rel[:4] == 0).all()


def test_lat_hist_counts_every_issue():
    """The QoS histogram is maintained on the same do_issue commit as the
    per-source issue counter: row sums must match exactly."""
    cfg = CFG3
    _, _, dram = sim.simulate_debug(cfg, "frfcfs", _nclass_pool(),
                                    np.ones(cfg.n_src, bool), 1_500)
    np.testing.assert_array_equal(dram["lat_hist"].sum(-1), dram["issued"])
    assert dram["issued"].sum() > 0


def test_frame_release_offset_is_bounded_and_stateless():
    jitter = np.array([0, 5, 12], np.int32)
    offs = np.stack([
        np.asarray(engine.frame_release_offset(3, np.int32(f), jitter))
        for f in range(32)])                                  # (F, S)
    assert (offs >= 0).all() and (offs <= jitter[None, :]).all()
    assert (offs[:, 0] == 0).all()          # zero jitter -> offset 0
    assert len(np.unique(offs[:, 2])) > 1   # hash actually varies by frame
    again = np.asarray(engine.frame_release_offset(3, np.int32(7), jitter))
    np.testing.assert_array_equal(again, offs[7])


def test_jitter_delays_release_not_accounting():
    """Jitter shifts emission inside the frame but the deadline stream
    (boundaries, met+missed identity) is untouched."""
    m0 = _run(CFG3, "frfcfs", _nclass_pool(jitter=(0, 0)))
    mj = _run(CFG3, "frfcfs", _nclass_pool(jitter=(120, 90)))
    for m in (m0, mj):
        np.testing.assert_array_equal(
            m["frames_released"][0, 4:], [_expected_frames(500),
                                          _expected_frames(400)])
    # the jittered run emitted through a shorter effective window
    assert mj["emitted"][0, 4:].sum() <= m0["emitted"][0, 4:].sum()


# ---------------------------------------------------------------------------
# stacked-path parity for the new pool keys
# ---------------------------------------------------------------------------

def test_stacked_parity_on_3class_pool():
    """Every stackable policy's slice of the stacked run must equal its
    per-policy run on a 3-class pool — the new keys (src_class, dl_jitter,
    frames_released, lat_hist, sq_urgent_adm) ride the union schema."""
    cfg = CFG3
    pool, active = _nclass_pool(), np.ones((1, cfg.n_src), bool)
    fam = sim.stackable_names(cfg)
    assert "squash_prio" in fam
    stacked = sim.simulate_stacked(cfg, fam, _batch(pool), active,
                                   CYCLES, WARMUP)
    for pol in fam:
        solo = _run(cfg, pol, pool)
        for k in solo:
            if k == "sim_steps":
                # driver property: the stacked family shares ONE
                # variable-step loop, so its step count is family-common
                continue
            np.testing.assert_array_equal(
                stacked[pol][k], solo[k], err_msg=f"{pol}:{k}")


def test_squash_urgent_admissions_only_on_deadline_sources():
    m = _run(CFG3, "squash_prio", _nclass_pool(), n_cycles=4_000)
    ua = m["urgent_admits"][0]
    assert ua[4:].sum() > 0, "HWA mix never hit the urgent tier"
    assert (ua[:4] == 0).all(), "urgent tier admitted a non-deadline source"
    # per-policy runs of urgent-tier-free policies don't grow the key
    assert "urgent_admits" not in _run(CFG3, "frfcfs", _nclass_pool())


# ---------------------------------------------------------------------------
# measurement-only contract + per-class reductions
# ---------------------------------------------------------------------------

def test_qos_disabled_only_removes_the_histogram():
    off = CFG3.replace(qos_enabled=False)
    assert qos.qos_state(off) == {}
    m_on = _run(CFG3, "atlas", _nclass_pool())
    m_off = _run(off, "atlas", _nclass_pool())
    assert set(m_on) - set(m_off) == {"lat_hist"}
    for k in m_off:
        np.testing.assert_array_equal(m_on[k], m_off[k], err_msg=k)


def test_qos_breakdown_reductions():
    cfg = CFG3
    pool = _nclass_pool()
    m = _run(cfg, "sms_dash", pool)
    qb = met.qos_breakdown(cfg, m, _batch(pool))
    assert 0.0 <= qb["dl_met_rate"][0] <= 1.0
    assert qb["frames_released"][0] == \
        _expected_frames(500) + _expected_frames(400)
    edges = qos.bin_upper_edges(cfg)
    for kname in ("cpu", "gpu", "hwa"):
        p95, p99 = qb[f"lat_p95_{kname}"][0], qb[f"lat_p99_{kname}"][0]
        assert 0 < p95 <= p99 <= edges[-1]
    # hand-rolled p99 of the CPU-pooled histogram must agree
    pooled = np.asarray(m["lat_hist"][0, :3]).sum(0)
    np.testing.assert_allclose(
        qb["lat_p99_cpu"][0], met.hist_quantile(pooled, edges, 0.99))


def test_class_masked_max_slowdown():
    s = np.array([1.5, 3.0, 2.0, 4.0])
    cls = np.array([CLS_CPU, CLS_CPU, CLS_GPU, CLS_HWA])
    assert met.max_slowdown(s) == 4.0
    assert met.max_slowdown(s, cls == CLS_CPU) == 3.0
    assert met.max_slowdown(s, cls == CLS_HWA) == 4.0
    assert np.isnan(met.max_slowdown(s, cls == 99))
