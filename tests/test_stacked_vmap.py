"""Stacked cross-policy execution: equivalence + hot-loop invariants.

The stacked path (schedulers.make_stacked_step) runs the whole stackable
`CentralizedPolicy` family as one scan over states stacked on a leading
policy axis. Contract, checked here:

  * every policy's slice is BIT-identical to its standalone run — pinned
    against the same golden digests `test_policy_registry` uses, and
    cross-checked against the vmapped `simulate` path metric-for-metric;
  * the stacked step keeps hot-loop rule 1: sort primitives appear only
    inside cond branches (each policy's t-only boundary predicate stays a
    genuine scalar cond on its own slice — the reason dispatch is per
    policy index rather than a batched `lax.switch`, which would dissolve
    the nested conds under vmap);
  * the union state schema refuses shape/dtype collisions instead of
    silently mis-padding;
  * stackability is an explicit opt-in: SMS-style protocols and configured
    variants (sms_dash) stay on the per-policy path.
"""
import hashlib
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import energy, engine, qos
from repro.core import policy as policy_api
from repro.core import schedulers
from repro.core import simulator as sim
from repro.core.params import SimConfig

CFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3)
SORT_PRIMS = {"sort"}

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_policy_states.json").read_text())

FAMILY = sim.stackable_names(CFG)


def _golden_pool(cfg):
    """Must match the capture-time generator exactly (seed 42)."""
    rng = np.random.RandomState(42)
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
        "dl_period": np.zeros(S, np.int32),
        "dl_reqs": np.zeros(S, np.int32),
    }
    pool["dl_period"][0] = 400
    pool["dl_reqs"][0] = 35
    return pool


def _digest(tree):
    out = {}
    for key in sorted(tree):
        if key.startswith("_"):
            continue
        v = np.ascontiguousarray(tree[key])
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
        out[key] = h.hexdigest()
    return out


# ---------------------------------------------------------------------------
# bit-identity: stacked slices vs the pre-stacking golden digests
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stacked_final_states():
    """One stacked run of the whole family at the golden config."""
    return sim.simulate_debug_stacked(
        CFG, FAMILY, _golden_pool(CFG), np.ones(CFG.n_src, bool),
        n_cycles=1_500)


@pytest.mark.parametrize("policy_name",
                         [n for n in FAMILY if n in GOLDEN])
def test_stacked_slice_bit_identical_to_golden(policy_name,
                                               stacked_final_states):
    st_f, sched_f, dram_f = stacked_final_states[policy_name]
    g = GOLDEN[policy_name]
    for part, tree in (("src", st_f), ("dram", dram_f)):
        new = _digest(tree)
        # energy/QoS counters and the N-class frame accounting are
        # additive-only extras on the stacked path too: every pre-existing
        # golden key must still match bit-for-bit
        allowed = set(energy.STATE_KEYS) | set(qos.STATE_KEYS) \
            if part == "dram" else set(engine.NCLASS_SRC_KEYS)
        assert set(new) ^ set(g[part]) <= allowed, \
            f"{policy_name} {part} keys drifted: {set(new) ^ set(g[part])}"
        for k, h in g[part].items():
            assert new[k] == h, f"{policy_name} {part}[{k}] diverged"
    sched = _digest(sched_f)
    shared = set(sched) & set(g["sched"])
    assert {"valid", "src", "bank", "row", "birth", "marked"} <= shared
    for k in shared:
        assert sched[k] == g["sched"][k], f"{policy_name} sched[{k}] diverged"


@pytest.mark.parametrize("policy_name",
                         [n for n in FAMILY if n not in GOLDEN])
def test_stacked_slice_bit_identical_to_debug(policy_name,
                                              stacked_final_states):
    """Policies younger than the golden capture (bliss, squash_prio):
    compare the stacked slice against a fresh standalone run instead."""
    ref = sim.simulate_debug(CFG, policy_name, _golden_pool(CFG),
                             np.ones(CFG.n_src, bool), n_cycles=1_500)
    got = stacked_final_states[policy_name]
    for part, (r, s) in zip(("src", "sched", "dram"), zip(ref, got)):
        rd, sd = _digest(r), _digest(s)
        assert set(sd) == set(rd), f"{policy_name} {part} keys drifted"
        for k in rd:
            assert sd[k] == rd[k], f"{policy_name} {part}[{k}] diverged"


def test_stacked_metrics_match_per_policy_simulate():
    """The jitted (workload-vmapped) stacked path == per-policy simulate."""
    rng = np.random.RandomState(3)
    W, S = 2, CFG.n_src
    mpki = rng.uniform(2, 40, (W, S)).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, (W, S)).astype(np.float32),
        "blp": rng.randint(1, 7, (W, S)).astype(np.int32),
        "is_gpu": np.tile([False] * CFG.n_cpu + [True], (W, 1)),
    }
    active = np.ones((W, S), bool)
    fam = FAMILY[:3]        # keep suite time down; digests cover all slices
    stk = sim.simulate_stacked(CFG, fam, pool, active,
                               n_cycles=600, warmup=100)
    for pol in fam:
        ref = sim.simulate(CFG, pol, pool, active, n_cycles=600, warmup=100)
        for k in ref:
            if k == "sim_steps":
                # driver property, not a policy metric: the stacked family
                # shares ONE loop, so its step count is the min over every
                # slice's witnesses — not any single policy's own count
                continue
            np.testing.assert_array_equal(
                ref[k], stk[pol][k], err_msg=f"{pol}:{k}")


# ---------------------------------------------------------------------------
# hot-loop invariant: one stacked step, sorts still only behind conds
# ---------------------------------------------------------------------------

def _stacked_step_jaxpr():
    pols, carry = sim._init_stacked(CFG, FAMILY)
    S = CFG.n_src
    pool = {k: jnp.zeros((S,), jnp.float32)
            for k in ("mpki", "inst_per_miss", "rbl")}
    pool.update(blp=jnp.ones((S,), jnp.int32),
                is_gpu=jnp.zeros((S,), bool))
    step = schedulers.make_stacked_step(CFG, pols,
                                        sim.prepare_pool(pool, (S,)),
                                        jnp.ones((S,), bool))
    return jax.make_jaxpr(step)(carry, jnp.int32(5))


def test_stacked_step_no_unconditional_sorts():
    """The whole family's cycle in ONE jaxpr, ranking still cond-gated."""
    jx = _stacked_step_jaxpr()
    prims = list(compat.walk_primitives(jx.jaxpr))
    uncond = [p for p, in_cond in prims if p in SORT_PRIMS and not in_cond]
    assert not uncond, (
        f"stacked step: {len(uncond)} unconditional sort op(s) — a policy's "
        f"ranking escaped its boundary cond on the stacked path")
    # non-vacuity: the ranked policies' boundary sorts are in there, gated
    gated = [p for p, in_cond in prims if p in SORT_PRIMS and in_cond]
    assert len(gated) >= 3, f"expected the family's ranking sorts: {gated}"


# ---------------------------------------------------------------------------
# schema + opt-in surface
# ---------------------------------------------------------------------------

def test_stackable_surface():
    assert set(FAMILY) == {"frfcfs", "atlas", "parbs", "tcm", "bliss",
                           "squash_prio"}
    assert not policy_api.is_stackable("sms", CFG)
    # sms_dash is a configured variant: configure() changes cfg, so it must
    # never slip into a stacked group even if marked stackable
    assert not policy_api.is_stackable("sms_dash", CFG)


def test_union_state_pads_and_rejects_collisions():
    pols = [policy_api.get(n) for n in FAMILY]
    padded = schedulers.stacked_union_state(CFG, pols)
    keys = set(padded[0])
    for p, s in zip(pols, padded):
        assert set(s) == keys, p.name
        for k, v in p.init_state(CFG).items():       # own state not padded
            assert s[k].shape == v.shape and s[k].dtype == v.dtype

    class Collider:
        name = "collider"

        def init_state(self, cfg):
            return {"pri_src": jnp.zeros((1,), jnp.float32)}   # wrong schema

    with pytest.raises(ValueError, match="collision"):
        schedulers.stacked_union_state(CFG, pols + [Collider()])
