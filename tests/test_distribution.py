"""Distribution: sharding-rule legality for every arch, multi-device pjit
end-to-end (subprocess with forced host devices), GPipe, elastic restore,
and dry-run artifact validation."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_cells
from repro.configs.registry import ARCH_IDS, get_config

REPO = Path(__file__).resolve().parents[1]


def _run_sub(code: str, devices: int = 8, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharding_rules_legal(arch):
    """Every param's PartitionSpec divides its shape on the 16x16 mesh."""
    from jax.sharding import PartitionSpec
    from repro.distributed import sharding as shlib
    from repro.models.registry import get_model

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
        devices = np.empty((16, 16), object)

    cfg = get_config(arch)
    bundle = get_model(cfg)
    rules = shlib.axis_rules(cfg, FakeMesh())
    axes_tree = bundle.axes()
    abstract = bundle.abstract_params()
    leaves_ax = jax.tree_util.tree_leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))
    leaves_ab = jax.tree_util.tree_leaves(abstract)
    assert len(leaves_ax) == len(leaves_ab)
    for axes, av in zip(leaves_ax, leaves_ab):
        assert len(axes) == len(av.shape), f"{arch}: {axes} vs {av.shape}"
        used = set()
        for ax_name, dim in zip(axes, av.shape):
            m = rules.get(ax_name)
            if m is None or m in used:
                continue
            used.add(m)
            assert dim % 16 == 0, \
                f"{arch}: axis {ax_name} dim {dim} not divisible by 16"


def test_pjit_train_step_multidevice():
    """Real 2x4 mesh end-to-end train step (8 host devices, subprocess)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import reduced, RunConfig, ShapeConfig
        from repro.configs.registry import get_config
        from repro.models.registry import get_model
        from repro.train import steps as steps_lib
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, synthetic_batch
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(get_config("qwen1.5-4b"), n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                      vocab_size=256)
        run = RunConfig(compute_dtype="float32", remat="full", lr=1e-3)
        shape = ShapeConfig("t", "train", 32, 8)
        with mesh:
            step, in_sh = steps_lib.build_train_step(cfg, run, mesh, shape)
            bundle = get_model(cfg)
            params = bundle.init(jax.random.PRNGKey(0))
            opt = adamw.init(params)
            dc = DataConfig(cfg.vocab_size, shape.seq_len, shape.global_batch)
            jstep = jax.jit(step, in_shardings=in_sh)
            losses = []
            for s in range(4):
                b = {k: jnp.asarray(v) for k, v in
                     synthetic_batch(dc, s).items()}
                params, opt, _, m = jstep(params, opt, jnp.zeros(()), b,
                                          jnp.int32(s))
                losses.append(float(m["loss"]))
            assert all(np.isfinite(losses)), losses
            assert losses[-1] < losses[0], losses
            print("LOSSES", [round(l, 3) for l in losses])
    """)
    assert "LOSSES" in out


def test_moe_ep_multidevice_matches_single():
    """shard_map EP on a 4-way model mesh == single-device reference."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import reduced, RunConfig
        from repro.configs.registry import get_config
        from repro.models import moe as moe_lib
        cfg = reduced(get_config("moonshot-v1-16b-a3b"), n_experts=8)
        run = RunConfig(compute_dtype="float32")
        rng = np.random.RandomState(0)
        p = {k: jnp.asarray(rng.randn(*d.shape) * 0.05, jnp.float32)
             for k, d in moe_lib.moe_defs(cfg).items()}
        x = jnp.asarray(rng.randn(4, 8, cfg.d_model), jnp.float32)
        ref, aux_ref = moe_lib.moe_apply(x, p, cfg, run, mesh=None)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh:
            f = jax.jit(lambda x, p: moe_lib.moe_apply(
                x, p, cfg, run, mesh=mesh, batch_axes=("data",)))
            y, aux = f(x, p)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(y),
                                   atol=2e-4, rtol=2e-3)
        # aux is mean-of-per-DP-shard losses vs the reference's global-batch
        # loss: same scale, not bitwise equal
        assert abs(float(aux_ref) - float(aux)) / float(aux_ref) < 0.2
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out


def test_gpipe_multidevice():
    out = _run_sub("""
        import jax, jax.numpy as jnp, functools
        from repro.distributed.pipeline import gpipe_apply
        mesh = jax.make_mesh((4,), ("pod",))
        W = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
        fn = lambda w, h: jnp.tanh(h @ w)
        out = gpipe_apply(fn, W, x, n_micro=4, mesh=mesh)
        ref = functools.reduce(lambda h, i: jnp.tanh(h @ W[i]), range(4), x)
        assert float(jnp.abs(out - ref).max()) < 1e-5
        g = jax.grad(lambda W: gpipe_apply(fn, W, x, 4, mesh).sum())(W)
        gr = jax.grad(lambda W: functools.reduce(
            lambda h, i: jnp.tanh(h @ W[i]), range(4), x).sum())(W)
        assert float(jnp.abs(g - gr).max()) < 1e-4
        print("GPIPE_OK")
    """, devices=4)
    assert "GPIPE_OK" in out


def test_elastic_restore_across_meshes():
    """Save under a 4-device mesh, restore+train under 2 devices."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, tempfile, subprocess, sys, os, textwrap
        from repro.configs.base import reduced, RunConfig, ShapeConfig
        from repro.configs.registry import get_config
        from repro.launch.mesh import make_local_mesh
        from repro.train.trainer import Trainer
        d = tempfile.mkdtemp()
        cfg = reduced(get_config("qwen1.5-4b"), n_layers=2)
        run = RunConfig(compute_dtype="float32", remat="none", lr=1e-3)
        shape = ShapeConfig("t", "train", 32, 8)
        mesh = jax.make_mesh((4, 1), ("data", "model"))
        tr = Trainer(cfg, run, mesh, shape, ckpt_dir=d, ckpt_every=2)
        with mesh:
            tr.train(2)
        print("SAVED_DIR", d)
    """, devices=4)
    d = out.split("SAVED_DIR")[1].strip()
    out2 = _run_sub(f"""
        import jax
        from repro.configs.base import reduced, RunConfig, ShapeConfig
        from repro.configs.registry import get_config
        from repro.train.trainer import Trainer
        cfg = reduced(get_config("qwen1.5-4b"), n_layers=2)
        run = RunConfig(compute_dtype="float32", remat="none", lr=1e-3)
        shape = ShapeConfig("t", "train", 32, 8)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        tr = Trainer(cfg, run, mesh, shape, ckpt_dir={d!r}, ckpt_every=10)
        st = tr.maybe_restore()
        assert st is not None and st.step == 2, st
        with mesh:
            st = tr.train(2, state=st)
        assert st.step == 4
        print("ELASTIC_OK")
    """, devices=2)
    assert "ELASTIC_OK" in out2


def test_perf_knobs_preserve_semantics():
    """attn_pad_heads / attn_batch_reshard / decode knobs are pure layout
    optimizations: losses and decode logits must match the baseline."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.base import reduced, RunConfig, ShapeConfig
        from repro.configs.registry import get_config
        from repro.models.registry import get_model
        from repro.models import lm as lm_lib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # 3 heads don't divide model=4 -> pad/reshard paths exercised
        cfg = reduced(get_config("gemma2-2b"), n_layers=2, d_model=48,
                      n_heads=3, n_kv_heads=1, head_dim=16, d_ff=96,
                      vocab_size=128)
        bundle = get_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
        batch = {"tokens": toks, "labels": toks}
        base_run = RunConfig(compute_dtype="float32", remat="none")
        with mesh:
            ref = float(jax.jit(lambda p, b: bundle.train_loss(
                p, base_run, b, mesh=mesh))(params, batch))
            for knob in ("attn_pad_heads", "attn_batch_reshard"):
                run = dataclasses.replace(base_run, **{knob: True})
                got = float(jax.jit(lambda p, b: bundle.train_loss(
                    p, run, b, mesh=mesh))(params, batch))
                assert abs(got - ref) < 1e-4, (knob, got, ref)
        # decode knobs (single device path is fine for numerics)
        cache = bundle.init_cache(8, 16, dtype=jnp.float32)
        lg_ref, c2, lens = bundle.prefill(params, base_run, cache,
                                          toks[:, :15])
        d_ref, _ = bundle.decode_step(params, base_run, c2, toks[:, 15], lens)
        run = dataclasses.replace(base_run, decode_grouped=True,
                                  decode_slim_mask=True)
        d_opt, _ = bundle.decode_step(params, run, c2, toks[:, 15], lens)
        np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_opt),
                                   atol=1e-5, rtol=1e-5)
        print("KNOBS_OK")
    """)
    assert "KNOBS_OK" in out


# ---------------------------------------------------------------------------
# dry-run artifacts (deliverable e)
# ---------------------------------------------------------------------------

DRYRUN = REPO / "experiments" / "dryrun"


@pytest.mark.skipif(not DRYRUN.exists(), reason="dry-run not yet generated")
def test_dryrun_all_cells_present_and_clean():
    expected = []
    for arch in ARCH_IDS:
        for cell in shape_cells(arch):
            for mesh in ("single_pod", "multi_pod"):
                expected.append(f"{arch}__{cell}__{mesh}.json")
    missing, errors = [], []
    for name in expected:
        p = DRYRUN / name
        if not p.exists():
            missing.append(name)
            continue
        rec = json.loads(p.read_text())
        if "error" in rec:
            errors.append(name)
    assert not missing, f"missing dry-run cells: {missing}"
    assert not errors, f"failed dry-run cells: {errors}"


@pytest.mark.skipif(not DRYRUN.exists(), reason="dry-run not yet generated")
def test_dryrun_records_have_roofline_terms():
    for p in DRYRUN.glob("*__single_pod.json"):
        rec = json.loads(p.read_text())
        if "error" in rec:
            continue
        r = rec["roofline"]
        assert r["compute_s"] >= 0 and r["memory_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert rec["cost_analysis"].get("flops", 0) > 0
