"""Fault-tolerant benchmark sweeps: the degradation ladder, partial
results, strict mode, and cache-version eviction in benchmarks.common.

Dispatch failures are forced by monkeypatching the simulator entry points
for one policy; the ladder must recover every healthy slice, persist it
per-slice, and surface the poisoned slice as an uncached error entry
(tolerant) or an immediate re-raise (strict).
"""
import json

import numpy as np
import pytest

from benchmarks import common
from repro.core import simulator as sim
from repro.core import workloads as wl

CFG = common.parity_config(n_cpu=3)
WLS = wl.make_workloads(CFG.n_cpu, n_per_cat=1)
KW = dict(n_cycles=300, warmup=50)


@pytest.fixture
def exp_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "EXP_DIR", tmp_path)
    return tmp_path


def _poison(monkeypatch, bad_policy):
    """Make every dispatch path fail for `bad_policy` only. Pass a
    DEDICATED MonkeyPatch instance when the test needs to heal the fault
    mid-test — undoing the shared fixture instance would also revert the
    EXP_DIR redirect and write caches into the real experiments dir."""
    orig_stacked = sim.simulate_stacked_async
    orig_async = sim.simulate_async
    orig_sync = sim.simulate
    orig_grid_async = sim.simulate_grid_async
    orig_grid = sim.simulate_grid
    orig_sgrid = sim.simulate_stacked_grid_async

    def bad_stacked(cfg, pols, *a, **k):
        if bad_policy in pols:
            raise RuntimeError("boom-stacked")
        return orig_stacked(cfg, pols, *a, **k)

    def bad_async(cfg, pol, *a, **k):
        if pol == bad_policy:
            raise RuntimeError("boom-async")
        return orig_async(cfg, pol, *a, **k)

    def bad_sync(cfg, pol, *a, **k):
        if pol == bad_policy:
            raise RuntimeError("boom-sync")
        return orig_sync(cfg, pol, *a, **k)

    def bad_grid_async(cfg, pol, *a, **k):
        if pol == bad_policy:
            raise RuntimeError("boom-grid-async")
        return orig_grid_async(cfg, pol, *a, **k)

    def bad_grid(cfg, pol, *a, **k):
        if pol == bad_policy:
            raise RuntimeError("boom-grid")
        return orig_grid(cfg, pol, *a, **k)

    def bad_sgrid(cfg, slices, *a, **k):
        if any((s[0] if isinstance(s, tuple) else s) == bad_policy
               for s in slices):
            raise RuntimeError("boom-stacked-grid")
        return orig_sgrid(cfg, slices, *a, **k)

    monkeypatch.setattr(sim, "simulate_stacked_grid_async", bad_sgrid)
    monkeypatch.setattr(sim, "simulate_stacked_async", bad_stacked)
    monkeypatch.setattr(sim, "simulate_async", bad_async)
    monkeypatch.setattr(sim, "simulate", bad_sync)
    monkeypatch.setattr(sim, "simulate_grid_async", bad_grid_async)
    monkeypatch.setattr(sim, "simulate_grid", bad_grid)


def test_run_sweep_tolerant_partial_report(exp_dir):
    poison = pytest.MonkeyPatch()
    try:
        _poison(poison, "atlas")
        res = common.run_sweep(CFG, ["frfcfs", "atlas"], WLS, **KW)
    finally:
        poison.undo()
    assert "error" in res["atlas"] and "boom" in res["atlas"]["error"]
    assert "error" not in res["frfcfs"]
    assert res["frfcfs"]["agg"]["weighted_speedup"] > 0
    # healthy slice persisted per-slice; poisoned slice never cached
    assert list(exp_dir.glob("frfcfs_*.json"))
    assert not list(exp_dir.glob("atlas_*.json"))
    # resume: a re-run with the fault healed retries ONLY the failed slice
    res2 = common.run_sweep(CFG, ["frfcfs", "atlas"], WLS, **KW)
    assert "error" not in res2["atlas"]
    assert list(exp_dir.glob("atlas_*.json"))


def test_run_sweep_strict_raises(exp_dir, monkeypatch):
    _poison(monkeypatch, "atlas")
    with pytest.raises(RuntimeError, match="boom"):
        common.run_sweep(CFG, ["frfcfs", "atlas"], WLS, strict=True, **KW)


def test_run_grid_tolerant_partial_report(exp_dir, monkeypatch):
    _poison(monkeypatch, "atlas")
    specs = [("frfcfs", "ok-slice", {}),
             ("atlas", "bad-slice", {"atlas_epoch": 64})]
    res = common.run_grid(CFG, specs, WLS, **KW)
    assert "error" in res["bad-slice"]
    assert res["bad-slice"]["label"] == "bad-slice"
    assert "error" not in res["ok-slice"]
    assert res["ok-slice"]["agg"]["weighted_speedup"] > 0
    assert not list(exp_dir.glob("grid_atlas_*.json"))
    # the partial report keeps slices parallel to the request
    assert list(res) == ["ok-slice", "bad-slice"]


def test_run_grid_strict_raises(exp_dir, monkeypatch):
    _poison(monkeypatch, "atlas")
    specs = [("frfcfs", "ok-slice", {}),
             ("atlas", "bad-slice", {"atlas_epoch": 64})]
    with pytest.raises(RuntimeError, match="boom"):
        common.run_grid(CFG, specs, WLS, strict=True, **KW)


def test_fmt_cat_table_skips_error_entries(exp_dir, monkeypatch):
    _poison(monkeypatch, "atlas")
    res = common.run_sweep(CFG, ["frfcfs", "atlas"], WLS, **KW)
    table = common.fmt_cat_table(res, "weighted_speedup")
    lines = table.splitlines()
    assert any(line.startswith("atlas,ERROR:") for line in lines)
    assert any(line.startswith("frfcfs,") and "ERROR" not in line
               for line in lines)


def test_cache_version_stamped_and_stale_evicted(exp_dir):
    res = common.run_sweep(CFG, ["frfcfs"], WLS, **KW)
    assert res["frfcfs"]["cache_version"] == common.CACHE_VERSION
    path = next(exp_dir.glob("frfcfs_*.json"))
    saved = json.loads(path.read_text())
    assert saved["cache_version"] == common.CACHE_VERSION
    # tamper the stamp: the loader must evict and recompute, not serve it
    saved["cache_version"] = "ancient"
    saved["agg"]["weighted_speedup"] = -1.0
    path.write_text(json.dumps(saved))
    res2 = common.run_sweep(CFG, ["frfcfs"], WLS, **KW)
    assert res2["frfcfs"]["agg"]["weighted_speedup"] > 0
    assert json.loads(path.read_text())["cache_version"] \
        == common.CACHE_VERSION


def test_evict_stale_sweeps_directory(exp_dir):
    common.run_sweep(CFG, ["frfcfs"], WLS, **KW)
    fresh = {p.name for p in exp_dir.glob("*.json")}
    stale = exp_dir / "grid_old_deadbeef.json"
    stale.write_text(json.dumps({"cache_version": "ancient"}))
    corrupt = exp_dir / "frfcfs_corrupt.json"
    corrupt.write_text("{not json")
    gone = common.evict_stale()
    assert set(gone) == {stale.name, corrupt.name}
    assert not stale.exists() and not corrupt.exists()
    assert {p.name for p in exp_dir.glob("*.json")} == fresh


def test_alone_cache_versioned(exp_dir):
    common.run_sweep(CFG, ["frfcfs"], WLS, **KW)
    apath = next(exp_dir.glob("alone_frfcfs_*.json"))
    data = json.loads(apath.read_text())
    assert data["cache_version"] == common.CACHE_VERSION
    assert isinstance(data["alone"], dict) and data["alone"]
