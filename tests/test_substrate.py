"""Substrate: data pipeline determinism/elasticity, AdamW, checkpointing,
trainer fault tolerance, gradient compression."""
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep; pip install -r "
                    "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import RunConfig, ShapeConfig, reduced
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_checksum, host_iterator, \
    synthetic_batch
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.train.trainer import HostDelayInjector, StragglerPolicy, Trainer

DC = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic():
    a = synthetic_batch(DC, step=3)
    b = synthetic_batch(DC, step=3)
    assert batch_checksum(a) == batch_checksum(b)
    c = synthetic_batch(DC, step=4)
    assert batch_checksum(a) != batch_checksum(c)


@pytest.mark.parametrize("n_hosts", [1, 2, 4, 8])
def test_data_elastic_sharding_invariance(n_hosts):
    """Union of host shards == the global batch, for any host count."""
    full = synthetic_batch(DC, step=5)
    its = [host_iterator(DC, h, n_hosts, start_step=5)
           for h in range(n_hosts)]
    shards = [next(it) for it in its]
    merged = np.concatenate([s["tokens"] for s in shards], axis=0)
    np.testing.assert_array_equal(merged, full["tokens"])


def test_data_is_learnable_structure():
    """Bigram structure: next token is predictable within patterns."""
    b = synthetic_batch(DC, step=0)
    toks, labels = b["tokens"], b["labels"]
    inc = (labels == toks + 1).mean()
    assert inc > 0.5, f"pattern structure missing (inc={inc})"


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw.update(g, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


@settings(deadline=None, max_examples=20)
@given(st.floats(0.1, 10.0), st.integers(0, 1000))
def test_clip_by_global_norm_property(max_norm, seed):
    rng = np.random.RandomState(seed)
    g = {"a": jnp.asarray(rng.randn(7, 3), jnp.float32),
         "b": jnp.asarray(rng.randn(5), jnp.float32)}
    clipped, norm = adamw.clip_by_global_norm(g, max_norm)
    new_norm = float(adamw.global_norm(clipped))
    assert new_norm <= max_norm * 1.001
    if float(norm) <= max_norm:   # no-op when already inside the ball
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)


def test_cosine_schedule_shape():
    lr0 = adamw.cosine_schedule(jnp.int32(0), base_lr=1.0, warmup=10,
                                total=100)
    lr_w = adamw.cosine_schedule(jnp.int32(10), base_lr=1.0, warmup=10,
                                 total=100)
    lr_end = adamw.cosine_schedule(jnp.int32(100), base_lr=1.0, warmup=10,
                                   total=100)
    assert float(lr0) == 0.0
    assert float(lr_w) == pytest.approx(1.0, abs=1e-5)
    assert float(lr_end) == pytest.approx(0.1, abs=1e-3)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"layer": {"w": jnp.asarray(rng.randn(4, 3), jnp.float32),
                      "b": jnp.asarray(rng.randn(3), jnp.float32)},
            "stack": [jnp.asarray(rng.randn(2, 2), jnp.float32)
                      for _ in range(3)]}


def test_checkpoint_roundtrip_exact():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, t)
        assert ckpt.latest_step(d) == 7
        restored, manifest = ckpt.restore(d, 7, t)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(a, b), t, restored)
        assert manifest["step"] == 7


def test_checkpoint_atomic_and_prune():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, t)
        ckpt.prune_old(d, keep=2)
        steps = sorted(int(p.name.split("_")[1])
                       for p in Path(d).glob("step_*"))
        assert steps == [3, 4]
        assert not list(Path(d).glob(".tmp*")), "tmp dirs must not survive"


def test_checkpoint_shape_mismatch_raises():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, t)
        bad = {"layer": {"w": jnp.zeros((5, 3)), "b": jnp.zeros(3)},
               "stack": t["stack"]}
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(d, 1, bad)


def test_checkpoint_async():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        th = ckpt.save_async(d, 2, t)
        th.join()
        restored, _ = ckpt.restore(d, 2, t)
        np.testing.assert_array_equal(restored["layer"]["w"],
                                      t["layer"]["w"])


# ---------------------------------------------------------------------------
# trainer: loss goes down, resume, stragglers, compression
# ---------------------------------------------------------------------------

def _trainer(tmp, **kw):
    cfg = reduced(get_config("qwen1.5-4b"), n_layers=2)
    run = kw.pop("run", RunConfig(compute_dtype="float32", remat="none",
                                  lr=2e-3, warmup_steps=2, total_steps=50))
    shape = ShapeConfig("tiny", "train", 64, 8)
    return Trainer(cfg, run, make_local_mesh(), shape, ckpt_dir=tmp,
                   ckpt_every=4, **kw)


def test_trainer_loss_decreases_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d)
        tr.train(9)
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0]
        tr2 = _trainer(d)
        st = tr2.maybe_restore()
        assert st is not None and st.step == 8
        st = tr2.train(2, state=st)
        assert st.step == 10


def test_trainer_straggler_exclusion():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, n_hosts=4,
                      straggler=StragglerPolicy(action="exclude", patience=2),
                      injector=HostDelayInjector(delays={1: 50.0}))
        tr.train(5)
        assert tr.healthy_hosts == [0, 2, 3]
        assert any("excluded host 1" in e for e in tr.events)


def test_trainer_host_failure_detected():
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, n_hosts=3,
                      straggler=StragglerPolicy(action="exclude", patience=3),
                      injector=HostDelayInjector(fail_at={2: 3}))
        tr.train(5)
        assert 2 not in tr.healthy_hosts


def test_grad_compression_topk_trains():
    run = RunConfig(compute_dtype="float32", remat="none", lr=2e-3,
                    warmup_steps=2, total_steps=50,
                    grad_compression="topk", topk_ratio=0.2)
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(d, run=run)
        tr.train(8)
        losses = [m["loss"] for m in tr.metrics_log]
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0], "top-k + error feedback must learn"
