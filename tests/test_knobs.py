"""Tunable-knob contract (repro.core.params.Knobs): every knob-variant
slice of a batched grid run is bit-identical to the same values baked into
a legacy SimConfig run, per policy, with energy + QoS accounting on;
default-knob runs match the legacy path exactly; the variable-step skip
driver stays bit-identical at non-default knob points."""
import numpy as np
import pytest

from repro.core import params
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import Knobs, SimConfig

CFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24,
                fifo_size=5, dcs_size=3)
N_CYCLES, WARMUP = 1_500, 300

# one non-default value point per policy (value-like knobs only)
VALUE_POINTS = {
    "frfcfs": {"cpu_reserve": 0.25},
    "atlas": {"atlas_alpha": 0.75},
    "parbs": {"parbs_cap": 3},
    "tcm": {"tcm_lat_frac": 0.5},
    "bliss": {"bliss_threshold": 2},
    "squash_prio": {"squash_lead": 40, "squash_pb": 0.5},
    "sms": {"sjf_prob": 0.5, "batch_age_cap": 100, "dash": True},
}
# period-like knobs ride the static config per slice
PERIOD_POINTS = {
    "atlas": {"atlas_epoch": 1500},
    "tcm": {"tcm_quantum": 800},
    "bliss": {"bliss_clear_interval": 5000},
}


def _pool(cfg):
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=1)[:4]
    return wl.pool_batch(cfg, wls)


def _assert_equal(a, b, ctx, skip_keys=()):
    # urgent_admits surfaces whenever squash_prio is in the stacked family,
    # so a stacked slice may carry it while the solo run does not
    assert (set(a) ^ set(b)) <= {"urgent_admits"}, ctx
    for k in set(a) & set(b):
        if k in skip_keys:
            continue
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{ctx}: metric {k}")


@pytest.fixture(scope="module")
def pool():
    return _pool(CFG)


# ---------------------------------------------------------------------------
# (a) knob-variant slices == baked-SimConfig runs, per policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", sorted(VALUE_POINTS))
def test_grid_slice_matches_baked_config(pol, pool):
    assert CFG.energy_enabled and CFG.qos_enabled
    p, a = pool
    ov = VALUE_POINTS[pol]
    got = sim.simulate_grid(CFG, pol, [{}, ov], p, a, N_CYCLES, WARMUP)
    legacy_def = sim.simulate(CFG, pol, p, a, N_CYCLES, WARMUP)
    legacy_ov = sim.simulate(CFG.replace(**ov), pol, p, a, N_CYCLES, WARMUP)
    _assert_equal(got[0], legacy_def, f"{pol} default slice")
    _assert_equal(got[1], legacy_ov, f"{pol} variant slice")


def test_stacked_grid_matches_baked_configs(pool):
    """Policy x knob variants on ONE stacked slice axis, including a
    period-like override (per-slice static config) and a repeated policy."""
    p, a = pool
    slices = [(pol, {**VALUE_POINTS[pol], **PERIOD_POINTS.get(pol, {})})
              for pol in sorted(set(VALUE_POINTS) - {"sms"})] \
        + [("frfcfs", {})]
    got = sim.simulate_stacked_grid(CFG, slices, p, a, N_CYCLES, WARMUP)
    for (pol, ov), g in zip(slices, got):
        legacy = sim.simulate(CFG.replace(**ov), pol, p, a, N_CYCLES, WARMUP)
        # sim_steps is the shared family skip meter, not a policy metric
        _assert_equal(g, legacy, f"stacked {pol}@{ov}",
                      skip_keys=("sim_steps",))


def test_sms_dash_is_a_knob_point(pool):
    """sms_dash (registry variant) == plain sms at the dash=True knob
    point: the fork is gone, only the knob remains."""
    p, a = pool
    dash = sim.simulate(CFG, "sms_dash", p, a, N_CYCLES, WARMUP)
    knob = sim.simulate_grid(CFG, "sms", [{"dash": True}], p, a,
                             N_CYCLES, WARMUP)[0]
    _assert_equal(knob, dash, "sms_dash vs dash knob")


# ---------------------------------------------------------------------------
# (b) default knob point == legacy trace (golden digests stay unchanged)
# ---------------------------------------------------------------------------

def test_default_knobs_match_cfg():
    kn = Knobs.from_cfg(CFG)
    for f in params.KNOB_FIELDS:
        assert np.asarray(getattr(kn, f)).item() == \
            pytest.approx(getattr(CFG, f)), f


def test_default_grid_slice_is_legacy_run(pool):
    p, a = pool
    for pol in ("atlas", "sms"):
        got = sim.simulate_grid(CFG, pol, [{}], p, a, N_CYCLES, WARMUP)[0]
        legacy = sim.simulate(CFG, pol, p, a, N_CYCLES, WARMUP)
        _assert_equal(got, legacy, f"{pol} default point")


# ---------------------------------------------------------------------------
# (c) skip driver bit-identity at a non-default knob point
# ---------------------------------------------------------------------------

def test_skip_bit_identity_at_knob_point():
    cfg = SimConfig(n_cpu=3, n_gpu=1, n_hwa=2, n_channels=2, buf_entries=24,
                    fifo_size=5, dcs_size=3)
    p, a = wl.bursty_batch(cfg)
    point = {"batch_age_cap": 100, "cpu_reserve": 0.25}
    tick = sim.simulate_grid(cfg, "sms", [point], p, a, N_CYCLES, WARMUP,
                             skip=False)[0]
    skip = sim.simulate_grid(cfg, "sms", [point], p, a, N_CYCLES, WARMUP,
                             skip=True)[0]
    assert float(np.mean(skip["sim_steps"])) < N_CYCLES, \
        "skip driver processed every cycle: witnesses broken at knob point"
    _assert_equal(tick, skip, "sms ticked vs skip", skip_keys=("sim_steps",))


def test_stacked_skip_bit_identity_at_knob_points():
    cfg = SimConfig(n_cpu=3, n_gpu=1, n_hwa=2, n_channels=2, buf_entries=24,
                    fifo_size=5, dcs_size=3)
    p, a = wl.bursty_batch(cfg)
    slices = [("atlas", {"atlas_epoch": 1500, "atlas_alpha": 0.75}),
              ("frfcfs", {"cpu_reserve": 0.25}),
              ("bliss", {"bliss_threshold": 2,
                         "bliss_clear_interval": 5000})]
    tick = sim.simulate_stacked_grid(cfg, slices, p, a, N_CYCLES, WARMUP,
                                     skip=False)
    skip = sim.simulate_stacked_grid(cfg, slices, p, a, N_CYCLES, WARMUP,
                                     skip=True)
    assert float(np.mean(skip[0]["sim_steps"])) < N_CYCLES
    for (pol, ov), t, s in zip(slices, tick, skip):
        _assert_equal(t, s, f"stacked skip {pol}@{ov}",
                      skip_keys=("sim_steps",))


# ---------------------------------------------------------------------------
# schema guards
# ---------------------------------------------------------------------------

def test_period_knobs_rejected_as_value_overrides():
    with pytest.raises(ValueError, match="period"):
        Knobs.from_cfg(CFG, atlas_epoch=1500)
    with pytest.raises(ValueError):
        Knobs.from_cfg(CFG, not_a_knob=1)


def test_split_overrides_partitions():
    per, val = params.split_overrides(
        {"atlas_epoch": 1500, "atlas_alpha": 0.75})
    assert per == {"atlas_epoch": 1500} and val == {"atlas_alpha": 0.75}
    with pytest.raises(ValueError):
        params.split_overrides({"nope": 1})


def test_sms_dash_not_stackable():
    # configure_knobs is not the identity at any config -> per-policy path
    assert not policy_api.is_stackable("sms_dash", CFG)
    assert policy_api.is_stackable("frfcfs", CFG)
