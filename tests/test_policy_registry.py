"""The `MemoryPolicy` registry: refactor equivalence + new-policy smoke.

The golden digests in `golden_policy_states.json` were captured from the
pre-registry string-dispatch code (`simulate_debug` final raw state, per
key, sha1 over dtype/shape/bytes). The ported policies must stay
bit-identical: src and dram state must match key-for-key in both
directions; scheduler state must match on every key that survived the port
(per-policy state was slimmed — e.g. frfcfs no longer carries ATLAS's
`attained` — so legacy-only keys are allowed to disappear, but shared keys
may not drift).
"""
import json
import hashlib
from pathlib import Path

import numpy as np
import pytest

from repro.core import energy, engine, policy, qos
from repro.core import simulator as sim
from repro.core.params import SimConfig
from repro.serving.scheduler import SCHEDULERS as SERVING_SCHEDULERS

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_policy_states.json").read_text())

CFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24, fifo_size=5,
                dcs_size=3)
N_CYCLES = 1_500
# keys whose presence proves the sched comparison isn't vacuous
ESSENTIAL_SCHED = {
    "sms": ("f_len", "f_row", "d_len", "d_src", "drain_left", "rr_bank"),
    "centralized": ("valid", "src", "bank", "row", "birth", "marked"),
}


def _golden_pool(cfg):
    """Must match the capture-time generator exactly (seed 42)."""
    rng = np.random.RandomState(42)
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
        "dl_period": np.zeros(S, np.int32),
        "dl_reqs": np.zeros(S, np.int32),
    }
    pool["dl_period"][0] = 400
    pool["dl_reqs"][0] = 35
    return pool


def _digest(tree):
    out = {}
    for key in sorted(tree):
        if key.startswith("_"):
            continue
        v = np.ascontiguousarray(tree[key])
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
        out[key] = h.hexdigest()
    return out


@pytest.mark.parametrize("policy_name", sorted(GOLDEN))
def test_ported_policy_bit_identical(policy_name):
    # the goldens predate the energy subsystem; running them with it ON
    # proves the accounting is purely additive — every scheduling/service
    # key must still match bit-for-bit, and the only new dram keys allowed
    # are the energy counters themselves
    assert CFG.energy_enabled, "additivity check must run with energy on"
    st_f, sched_f, dram_f = sim.simulate_debug(
        CFG, policy_name, _golden_pool(CFG), np.ones(CFG.n_src, bool),
        n_cycles=N_CYCLES)
    g = GOLDEN[policy_name]
    for part, tree in (("src", st_f), ("dram", dram_f)):
        new = _digest(tree)
        # additive-only subsystems may add keys on top of the goldens:
        # energy + QoS counters (dram), N-class frame accounting (src)
        allowed = set(energy.STATE_KEYS) | set(qos.STATE_KEYS) \
            if part == "dram" else set(engine.NCLASS_SRC_KEYS)
        assert set(new) ^ set(g[part]) <= allowed, \
            f"{policy_name} {part} keys drifted: {set(new) ^ set(g[part])}"
        for k, h in g[part].items():
            assert new[k] == h, f"{policy_name} {part}[{k}] diverged"
    assert set(energy.STATE_KEYS) <= set(dram_f), \
        "energy counters missing — the additivity check would be vacuous"
    sched = _digest(sched_f)
    essential = ESSENTIAL_SCHED[
        "sms" if policy_name.startswith("sms") else "centralized"]
    for k in essential:
        assert k in sched and k in g["sched"], f"missing sched key {k}"
    for k in set(sched) & set(g["sched"]):
        assert sched[k] == g["sched"][k], f"{policy_name} sched[{k}] diverged"


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_registry_enumerations():
    assert set(sim.POLICIES) == {"frfcfs", "atlas", "parbs", "tcm", "sms",
                                 "bliss", "squash_prio"}
    assert set(sim.ALL_POLICIES) == set(sim.POLICIES) | {"sms_dash"}
    for name in sim.ALL_POLICIES:
        pol = policy.get(name)
        assert pol.name == name
        for attr in ("configure", "init_state", "tick", "select"):
            assert callable(getattr(pol, attr)), (name, attr)
    assert policy.get("sms_dash").variant_of == "sms"


def test_registry_rejects_duplicates_and_unknowns():
    policy.names()          # force lazy built-in registration (order-proof)
    with pytest.raises(ValueError, match="duplicate"):
        policy.POLICY_REGISTRY.register("sms")(object())
    with pytest.raises(KeyError, match="unknown"):
        policy.get("nonexistent-policy")


def test_serving_registry_same_mechanism():
    """Serving schedulers enumerate through the same Registry class."""
    assert isinstance(SERVING_SCHEDULERS, policy.Registry)
    assert set(SERVING_SCHEDULERS.names()) >= {"fcfs", "locality", "sms",
                                               "sms_adaptive"}
    sched = SERVING_SCHEDULERS.get("sms")(4, seed=0)
    assert sched.n_clients == 4


# ---------------------------------------------------------------------------
# new policies: end-to-end smoke + no CPU starvation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy_name", ["bliss", "squash_prio"])
def test_new_policy_runs_and_never_starves_cpus(policy_name):
    cfg = SimConfig(n_cpu=4, n_channels=2, buf_entries=48, fifo_size=6,
                    dcs_size=4)
    rng = np.random.RandomState(7)
    S = cfg.n_src
    mpki = rng.uniform(15, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.3, 0.95, S).astype(np.float32),
        "blp": rng.randint(2, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
    }
    active = np.ones(S, bool)
    st_f, sched_f, dram_f = sim.simulate_debug(cfg, policy_name, pool,
                                               active, n_cycles=4_000)
    # conservation: emitted = completed + pending + in-flight + buffered
    in_struct = np.zeros(S, np.int64)
    for c in range(cfg.n_channels):
        for e in range(cfg.buf_entries):
            if sched_f["valid"][c, e]:
                in_struct[sched_f["src"][c, e]] += 1
    np.testing.assert_array_equal(
        st_f["emitted"].astype(np.int64),
        st_f["completed"] + st_f["pend_valid"] + dram_f["ring"].sum(0)
        + in_struct)
    # every CPU source makes real progress despite the GPU stream
    cpu_done = st_f["completed"][:cfg.n_cpu]
    assert (cpu_done > 0).all(), f"{policy_name} starved a CPU: {cpu_done}"
    assert (st_f["insts_done"][:cfg.n_cpu] > 0).all()


def test_bliss_blacklists_the_streaming_gpu():
    """An unopposed high-RBL GPU stream must trip the consecutive-serve
    blacklist (near-idle CPUs so serves are actually back-to-back)."""
    cfg = SimConfig(n_cpu=2, n_channels=1, buf_entries=32,
                    bliss_clear_interval=100_000)
    S = cfg.n_src
    pool = {
        "mpki": np.asarray([0.5, 0.5, 1000.0], np.float32),
        "inst_per_miss": np.asarray([2000.0, 2000.0, 1.0], np.float32),
        "rbl": np.asarray([0.3, 0.3, 0.95], np.float32),
        "blp": np.asarray([2, 2, 4], np.int32),
        "is_gpu": np.asarray([False, False, True]),
    }
    _, sched_f, _ = sim.simulate_debug(cfg, "bliss", pool,
                                       np.ones(S, bool), n_cycles=3_000)
    assert bool(sched_f["blacklist"][2]), "GPU never blacklisted"
