"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev dep; pip install -r "
                    "requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

TOL = dict(atol=2e-3, rtol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,d,causal,window,softcap",
    [
        (2, 4, 2, 128, 128, 64, True, 0, 0.0),
        (1, 8, 4, 256, 256, 32, True, 64, 0.0),     # sliding window
        (1, 2, 2, 128, 256, 64, False, 0, 50.0),    # softcap, cross len
        (2, 6, 1, 64, 128, 128, True, 0, 0.0),      # MQA
        (1, 4, 4, 192, 192, 16, True, 128, 30.0),   # window + softcap
    ])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, d, causal, window,
                               softcap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    tol = TOL if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_flash_attention_block_invariance(block):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention(q, k, v, block_q=block, block_k=block,
                          interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,d,page,n_slots,P", [
    (2, 4, 2, 64, 16, 4, 32),
    (3, 8, 8, 32, 8, 6, 64),
    (1, 6, 2, 128, 32, 3, 16),
    (4, 2, 1, 64, 8, 8, 40),
])
def test_paged_attention_sweep(B, Hq, Hkv, d, page, n_slots, P, dtype):
    rng = np.random.RandomState(0)
    lengths = jnp.asarray(rng.randint(1, page * n_slots + 1, (B,)), jnp.int32)
    pt = jnp.asarray(rng.randint(0, P, (B, n_slots)), jnp.int32)
    q = jnp.asarray(rng.randn(B, Hq, d), dtype)
    kp = jnp.asarray(rng.randn(P, Hkv, page, d), dtype)
    vp = jnp.asarray(rng.randn(P, Hkv, page, d), dtype)
    out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, pt, lengths)
    tol = TOL if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@settings(deadline=None, max_examples=8)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 4),
       st.integers(0, 10_000))
def test_paged_attention_property(B, Hkv, g, seed):
    """Random lengths/page tables: kernel == oracle (hypothesis)."""
    rng = np.random.RandomState(seed)
    page, n_slots, d = 8, 3, 32
    P = B * n_slots + 2
    Hq = Hkv * g
    lengths = jnp.asarray(rng.randint(1, page * n_slots + 1, (B,)), jnp.int32)
    pt = jnp.asarray(rng.randint(0, P, (B, n_slots)), jnp.int32)
    q = jnp.asarray(rng.randn(B, Hq, d), jnp.float32)
    kp = jnp.asarray(rng.randn(P, Hkv, page, d), jnp.float32)
    vp = jnp.asarray(rng.randn(P, Hkv, page, d), jnp.float32)
    out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-4)


@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (2, 2, 64, 16, 16),
    (1, 4, 128, 32, 32),
    (1, 1, 96, 8, 96),       # single chunk
    (2, 1, 64, 16, 8),       # many small chunks
])
def test_mlstm_scan_kernel(B, H, S, dh, chunk):
    """Pallas chunkwise mLSTM vs the (recurrence-validated) XLA oracle,
    deliberately computed with a different chunk size."""
    from repro.kernels.mlstm_scan.kernel import mlstm_scan
    from repro.kernels.mlstm_scan.ref import mlstm_scan_ref
    rng = np.random.RandomState(B * 100 + S)
    q, k, v = (jnp.asarray(rng.randn(B, H, S, dh), jnp.float32)
               for _ in range(3))
    lf = jnp.asarray(np.log(rng.uniform(0.5, 0.99, (B, H, S))), jnp.float32)
    li = jnp.asarray(rng.randn(B, H, S) * 0.5, jnp.float32)
    out = mlstm_scan(q, k, v, lf, li, chunk=chunk, interpret=True)
    ref = mlstm_scan_ref(q, k, v, lf, li, chunk=8)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-4)


@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000))
def test_mlstm_scan_property(seed):
    from repro.kernels.mlstm_scan.kernel import mlstm_scan
    from repro.kernels.mlstm_scan.ref import mlstm_scan_ref
    rng = np.random.RandomState(seed)
    B, H, S, dh = 1, 2, 48, 8
    q, k, v = (jnp.asarray(rng.randn(B, H, S, dh), jnp.float32)
               for _ in range(3))
    lf = jnp.asarray(np.log(rng.uniform(0.3, 0.999, (B, H, S))), jnp.float32)
    li = jnp.asarray(rng.randn(B, H, S), jnp.float32)
    out = mlstm_scan(q, k, v, lf, li, chunk=16, interpret=True)
    ref = mlstm_scan_ref(q, k, v, lf, li, chunk=48)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-4)


def test_use_pallas_flag_in_model():
    """End-to-end: train_loss with run.use_pallas=True (flash kernel inside
    the scanned block) matches the XLA path."""
    import dataclasses
    from repro.configs.base import RunConfig, reduced
    from repro.configs.registry import get_config
    from repro.models.registry import get_model
    cfg = reduced(get_config("qwen1.5-4b"), n_layers=2, head_dim=32)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    base = RunConfig(compute_dtype="float32", remat="none")
    pall = dataclasses.replace(base, use_pallas=True)
    l0 = float(bundle.train_loss(params, base, batch))
    l1 = float(bundle.train_loss(params, pall, batch))
    assert abs(l0 - l1) < 1e-5, (l0, l1)


def test_flash_matches_model_attention_path():
    """Kernel agrees with the model-side chunked XLA attention."""
    from repro.models.common import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, H, S, d = 1, 4, 256, 32
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, H, d))
    v = jax.random.normal(ks[2], (B, S, H, d))
    xla = chunked_attention(q, k, v, causal=True, chunk=64)
    pal = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True, block_q=64,
                          block_k=64, interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(xla, pal, atol=2e-5, rtol=2e-4)
