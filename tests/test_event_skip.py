"""Event-driven cycle skipping: the variable-step driver contract.

The skipping driver (`_run_cycles` with a `make_skip_step` body) replaces
the fixed lax.scan with a while_loop that processes cycle t and then jumps
straight to the earliest witnessed next event. Contract, checked here:

  * SEMANTIC INVISIBILITY — ticked and skipping runs are BIT-identical:
    every metric (energy + QoS on) and every raw final-state array, for
    every registered policy, on both a busy 3-class mix and a sparse
    idle-heavy mix. `sim_steps` is the one intentional exception (it IS
    the skip measurement);
  * skipped spans charge background energy exactly: the integer
    standby/power-down counters partition every channel-cycle with no
    drift, and match the ticked accrual bit-for-bit;
  * the skip never jumps past an HWA frame release or a t-only boundary
    edge (epoch ranks, quantum shuffles, probabilistic redraws) — frame
    releases land cycle-exact and the boundary-policy states stay
    bit-identical on idle spans, where a late jump would starve the edge;
  * the PAR-BS amortized-rank residue fix: the stacked slice still
    matches the pre-refactor per-policy golden digests, running THROUGH
    the skipping driver.
"""
import hashlib
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import energy, engine, qos
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core.params import CLS_CPU, CLS_GPU, CLS_HWA, SimConfig

CFG = SimConfig(n_cpu=3, n_gpu=1, n_hwa=1, n_channels=2, buf_entries=24,
                fifo_size=5, dcs_size=3)
N_CYCLES = 1_500
ALL_POLICIES = list(policy_api.names())


def _mix_pool():
    """(W=2, S=5) batch: row 0 busy 3-class mix, row 1 sparse/idle-heavy
    (low-mpki CPUs + a slow frame HWA; GPU masked off via `active`)."""
    mpki = np.array([[25, 40, 18, 1000, 1000],
                     [0.5, 1.0, 0.8, 1000, 1000]], np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": np.tile(np.array([.5, .4, .6, .9, .85], np.float32), (2, 1)),
        "blp": np.tile(np.array([3, 2, 4, 4, 2], np.int32), (2, 1)),
        "is_gpu": np.tile(np.array([0, 0, 0, 1, 0], bool), (2, 1)),
        "src_class": np.tile(np.array(
            [CLS_CPU] * 3 + [CLS_GPU, CLS_HWA], np.int32), (2, 1)),
        "dl_period": np.tile(np.array([0, 0, 0, 0, 400], np.int32), (2, 1)),
        "dl_reqs": np.tile(np.array([0, 0, 0, 0, 20], np.int32), (2, 1)),
        "dl_jitter": np.tile(np.array([0, 0, 0, 0, 10], np.int32), (2, 1)),
    }
    active = np.array([[1, 1, 1, 1, 1],
                       [1, 1, 0, 0, 1]], bool)
    return pool, active


def _row(pool, active, i):
    return {k: v[i] for k, v in pool.items()}, active[i]


def _digest(tree):
    out = {}
    for key in sorted(tree):
        if key.startswith("_"):
            continue
        v = np.ascontiguousarray(tree[key])
        h = hashlib.sha1()
        h.update(str(v.dtype).encode())
        h.update(str(v.shape).encode())
        h.update(v.tobytes())
        out[key] = h.hexdigest()
    return out


# ---------------------------------------------------------------------------
# (a) ticked vs skipping bit-identity, every policy, energy + QoS on
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_metrics_bit_identical_and_skip_nonvacuous(pol):
    assert CFG.energy_enabled and CFG.qos_enabled
    pool, active = _mix_pool()
    ref = sim.simulate(CFG, pol, pool, active, N_CYCLES, 300, skip=False)
    got = sim.simulate(CFG, pol, pool, active, N_CYCLES, 300, skip=True)
    assert set(ref) == set(got)
    for k in ref:
        if k == "sim_steps":
            continue
        np.testing.assert_array_equal(ref[k], got[k], err_msg=f"{pol}:{k}")
    # ticked driver processes every cycle; the skipping one must actually
    # skip on the idle-heavy row or the whole apparatus is vacuous
    assert (ref["sim_steps"] == N_CYCLES).all(), pol
    assert got["sim_steps"][1] < 0.95 * N_CYCLES, \
        f"{pol}: no skip on the idle-heavy mix ({got['sim_steps'][1]})"


@pytest.mark.parametrize("pol", ["frfcfs", "atlas", "parbs", "squash_prio",
                                 "sms"])
def test_final_raw_state_bit_identical(pol):
    """Full-state digest equality on the sparse mix: covers per-cycle
    boundary machinery (atlas epoch ranks, squash urgency flips + redraws,
    SMS batch ageing) landing on exactly the right edges mid-idle-span."""
    pool, active = _mix_pool()
    pool1, act1 = _row(pool, active, 1)
    ref = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES, skip=False)
    got = sim.simulate_debug(CFG, pol, pool1, act1, N_CYCLES, skip=True)
    for part, (r, s) in zip(("src", "sched", "dram"), zip(ref, got)):
        rd, sd = _digest(r), _digest(s)
        assert set(sd) == set(rd), f"{pol} {part} keys drifted"
        for k in rd:
            assert sd[k] == rd[k], f"{pol} {part}[{k}] diverged"


# ---------------------------------------------------------------------------
# (b) skipped spans charge standby/power-down energy exactly
# ---------------------------------------------------------------------------

def test_skipped_span_background_accrual_exact():
    pool, active = _mix_pool()
    pool1, _ = _row(pool, active, 1)
    lone = np.zeros(CFG.n_src, bool)
    lone[0] = True                       # one sparse CPU: long idle spans
    _, _, d_ref = sim.simulate_debug(CFG, "frfcfs", pool1, lone, N_CYCLES,
                                     skip=False)
    _, _, d_got = sim.simulate_debug(CFG, "frfcfs", pool1, lone, N_CYCLES,
                                     skip=True)
    # integer counters: exact partition of every channel-cycle, and the
    # one-multiply span accrual reproduces the per-cycle walk bit-for-bit
    for d in (d_ref, d_got):
        assert int(d["sb_cycles"].sum() + d["pd_cycles"].sum()) \
            == CFG.n_channels * N_CYCLES
    for k in ("sb_cycles", "pd_cycles", "pd_down", "e_wake", "busy_until"):
        np.testing.assert_array_equal(d_ref[k], d_got[k], err_msg=k)
    assert int(d_got["pd_cycles"].sum()) > 0, "span never entered power-down"
    # non-vacuity: this scenario must actually exercise long skips
    m = sim.simulate(CFG, "frfcfs", {k: v[None] for k, v in pool1.items()},
                     lone[None], N_CYCLES, 0, skip=True)
    assert m["sim_steps"][0] < 0.3 * N_CYCLES


# ---------------------------------------------------------------------------
# (c) skips stop at HWA frame releases and t-only boundary edges
# ---------------------------------------------------------------------------

def test_skip_stops_at_hwa_frame_releases():
    """`frames_released` counts deadline-frame starts cycle-exactly; a jump
    past a release would undercount it (and desync every deadline metric).
    Run mostly-idle so releases are the dominant wake-up reason."""
    pool, active = _mix_pool()
    pool1, act1 = _row(pool, active, 1)
    st_ref, _, _ = sim.simulate_debug(CFG, "frfcfs", pool1, act1, N_CYCLES,
                                      skip=False)
    st_got, _, _ = sim.simulate_debug(CFG, "frfcfs", pool1, act1, N_CYCLES,
                                      skip=True)
    np.testing.assert_array_equal(st_ref["frames_released"],
                                  st_got["frames_released"])
    hwa = CFG.n_src - 1
    assert int(st_got["frames_released"][hwa]) == (N_CYCLES - 1) // 400, \
        "skipping run missed a frame release"


# ---------------------------------------------------------------------------
# (d) PAR-BS residue fix: stacked slice vs pre-refactor golden, skipping
# ---------------------------------------------------------------------------

GOLDEN = json.loads(
    (Path(__file__).parent / "golden_policy_states.json").read_text())
GCFG = SimConfig(n_cpu=3, n_gpu=1, n_channels=2, buf_entries=24, fifo_size=5,
                 dcs_size=3)


def _golden_pool(cfg):
    rng = np.random.RandomState(42)
    S = cfg.n_src
    mpki = rng.uniform(2, 40, S).astype(np.float32)
    pool = {
        "mpki": mpki,
        "inst_per_miss": np.maximum(1000.0 / mpki, 1.0).astype(np.float32),
        "rbl": rng.uniform(0.1, 0.95, S).astype(np.float32),
        "blp": rng.randint(1, 7, S).astype(np.int32),
        "is_gpu": np.asarray([False] * cfg.n_cpu + [True]),
        "dl_period": np.zeros(S, np.int32),
        "dl_reqs": np.zeros(S, np.int32),
    }
    pool["dl_period"][0] = 400
    pool["dl_reqs"][0] = 35
    return pool


def test_parbs_stacked_slice_matches_golden_through_skip_driver():
    """The amortized-rank reformulation (no per-cycle sort, no batched
    cond residue) + the skipping driver, against the digests captured
    before either existed: the batch machinery is bit-preserved."""
    fam = sim.stackable_names(GCFG)
    out = sim.simulate_debug_stacked(GCFG, fam, _golden_pool(GCFG),
                                     np.ones(GCFG.n_src, bool),
                                     n_cycles=1_500, skip=True)
    st_f, sched_f, dram_f = out["parbs"]
    g = GOLDEN["parbs"]
    for part, tree in (("src", st_f), ("dram", dram_f)):
        new = _digest(tree)
        allowed = set(energy.STATE_KEYS) | set(qos.STATE_KEYS) \
            if part == "dram" else set(engine.NCLASS_SRC_KEYS)
        assert set(new) ^ set(g[part]) <= allowed
        for k, h in g[part].items():
            assert new[k] == h, f"parbs {part}[{k}] diverged"
    sched = _digest(sched_f)
    shared = set(sched) & set(g["sched"])
    assert {"valid", "src", "bank", "row", "birth", "marked"} <= shared
    for k in shared:
        assert sched[k] == g["sched"][k], f"parbs sched[{k}] diverged"
