"""Assigned-architecture configs: exact spec values + registry."""
import pytest

from repro.configs.base import LONG_CONTEXT_ARCHS, SHAPES, shape_cells
from repro.configs.registry import ARCH_IDS, all_configs, get_config

SPEC = {  # (layers, d_model, heads, kv, d_ff, vocab)
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    c = get_config(arch)
    L, d, h, kv, ff, v = SPEC[arch]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v)


def test_all_ten_archs_registered():
    assert len(all_configs()) == 10


def test_moe_details():
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.top_k) == (16, 1)
    ms = get_config("moonshot-v1-16b-a3b")
    assert (ms.n_experts, ms.top_k) == (64, 6)


def test_param_counts_in_range():
    assert 90e9 < get_config("command-r-plus-104b").n_params() < 120e9
    assert 100e9 < get_config("qwen1.5-110b").n_params() < 125e9
    l4 = get_config("llama4-scout-17b-a16e")
    assert 14e9 < l4.n_active_params() < 20e9
    assert 90e9 < l4.n_params() < 120e9
    assert 0.1e9 < get_config("xlstm-125m").n_params() < 0.2e9


def test_long_context_rule():
    """long_500k only for sub-quadratic archs (ssm/hybrid)."""
    for arch in ARCH_IDS:
        cells = shape_cells(arch)
        assert ("long_500k" in cells) == (arch in LONG_CONTEXT_ARCHS)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)


def test_shape_table():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["decode_32k"].kind == "decode"
