"""Production mesh definition (a function — importing never touches devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).

    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); the ``pod`` axis
    carries data parallelism by default (lowest bisection bandwidth -> lowest
    communication volume), and optionally pipeline stages (see
    ``repro.distributed.pipeline``).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many real devices exist (tests/examples)."""
    n = len(jax.devices())
    if model * data > n:
        model, data = 1, min(data, n)
    return jax.make_mesh((data, model), ("data", "model"))
