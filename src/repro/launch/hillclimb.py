import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Each iteration re-lowers a cell with one RunConfig knob flipped and records
the calibrated roofline-term deltas against the cell's baseline into
experiments/perf/<cell>.json. EXPERIMENTS.md §Perf is written from these
records.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|all]
"""
import argparse
import json
from pathlib import Path

from repro.configs.base import SHAPES, RunConfig
from repro.launch import dryrun

PERF_DIR = Path(__file__).resolve().parents[3] / "experiments" / "perf"

# (cell_id, arch, shape, [(tag, hypothesis, run_overrides)...])
PLANS = [
    ("A", "gemma2-2b", "train_4k", [
        ("reshard_attn",
         "8 q-heads < 16-way TP leaves attention REPLICATED over `model`: "
         "~16x redundant attention flops/chip. Respreading the batch over "
         "(data,model) for the attention op makes it pure-DP; predict the "
         "compute term drops by ~the replicated attention share (napkin: "
         "attention ~45% of per-chip HLO flops at 4k seq -> ~40% compute-term "
         "cut) at the cost of 2 activation reshards/layer (collective +"
         "~4*B*S*d bytes/layer).",
         {"attn_batch_reshard": True}),
        ("remat_dots",
         "remat='full' recomputes the whole forward in backward (~1.33x "
         "flops). Policy 'dots' saves matmul outputs: predict ~15-25% "
         "compute-term cut, memory term rises by saved activations.",
         {"remat": "dots"}),
        ("reshard_attn+dots",
         "Compose both wins if they are independent terms.",
         {"attn_batch_reshard": True, "remat": "dots"}),
        ("pad_heads",
         "reshard_attn cut memory -73% but its 2 reshards/layer made "
         "collective the new bound (10.6s). Alternative: PAD q-heads 8->16 "
         "so attention shards over `model` with ZERO extra collectives, at "
         "2x attention flops (padded heads attend to zeros). Predict: "
         "memory term ~= reshard variant, collective back to ~baseline -> "
         "net step bound ~3.8s vs 14.2s baseline (3.7x).",
         {"attn_pad_heads": True}),
        ("pad_heads+dots",
         "Compose the winning sharding fix with the remat policy (judge "
         "remat part on raw).",
         {"attn_pad_heads": True, "remat": "dots"}),
    ]),
    ("B", "command-r-plus-104b", "decode_32k", [
        ("cache_anchor",
         "Baseline decode is COLLECTIVE-bound at 2.75s/step (~137 GB/step): "
         "the HLO shows SPMD 'involuntary full rematerialization' on the "
         "cache update — the broadcast new-k operand's sharding mismatches "
         "the sequence-sharded cache, so SPMD all-gathers the 8.6 GB cache "
         "every layer. Anchoring the updated cache with a sharding "
         "constraint should reshard the (tiny) broadcast instead: predict "
         "collective term drops by >100x to the all-reduce floor.",
         {"decode_cache_anchor": True}),
        ("grouped_kv",
         "Decode expands KV 8->96 heads before the attention einsums: the "
         "dominant HBM traffic (32k-seq KV cache) is read 12x per step. "
         "Grouped-query attention reads it once: predict the memory term "
         "drops toward cache-size/HBM_BW (~12x cut on the KV read, bounded "
         "by the cache-update write traffic).",
         {"decode_grouped": True}),
        ("anchor+grouped",
         "Compose: memory-bound after the anchor fix, so the grouped-KV "
         "read cut should now move the dominant term.",
         {"decode_cache_anchor": True, "decode_grouped": True}),
        ("grouped+slim",
         "After grouped-KV the remaining bytes include a redundant causal "
         "mask pass over (B, 32k) per layer: for a single query the kv_len "
         "mask subsumes causality. Predict a further single-digit% memory "
         "cut.",
         {"decode_grouped": True, "decode_slim_mask": True,
          "decode_cache_anchor": True}),
    ]),
    ("C", "qwen1.5-110b", "train_4k", [
        ("zero1",
         "Optimizer state (2x f32 moments of 111B params / 256 chips) "
         "dominates per-chip memory traffic; ZeRO-1 shards moments over "
         "`data` (16x): predict the memory term drops by ~the moment-update "
         "traffic share; collective bytes roughly unchanged (grad "
         "reduce-scatter replaces part of the all-reduce).",
         {"zero1": True}),
        ("remat_dots",
         "remat='full' recomputes each block in backward; 'dots' saves "
         "matmul outputs. NOTE: judge on RAW scanned terms — the unrolled "
         "calibration variants CSE the recompute away, hiding remat cost.",
         {"remat": "dots"}),
        ("bf16_master",
         "Halve param+moment traffic: bf16 master params and moments "
         "(production uses stochastic rounding on TPU). Predict the memory "
         "term drops by ~the optimizer-traffic share (params+grads+2 "
         "moments read+write ~10 passes over 434 MB/chip).",
         {"param_dtype_bf16": True}),
    ]),
    # D: worst roofline fraction in the whole table (whisper train, 0.050)
    ("D", "whisper-large-v3", "train_4k", [
        ("pad_heads",
         "whisper has 20 heads (MHA) < no multiple of TP16 -> encoder+decoder "
         "self/cross attention all replicated 16x over `model`. Padding "
         "20->32 heads shards attention 16-ways at 1.6x padded flops: "
         "predict the memory term (dominated by replicated (B,S,S) "
         "attention traffic) drops ~8x and compute/chip drops ~10x.",
         {"attn_pad_heads": True}),
        ("pad_heads+dots",
         "Compose head padding with the lighter remat policy (judged on "
         "raw terms; see cell C note on CSE).",
         {"attn_pad_heads": True, "remat": "dots"}),
    ]),
    # E: generalization check — the OTHER collective-bound decode cell must
    # be fixed by the same knobs found in cell B
    ("E", "llava-next-mistral-7b", "decode_32k", [
        ("grouped_kv",
         "llava (mistral backbone, kv=8 < TP16) shows the same "
         "collective-bound decode pathology as cell B (1.38 s/step of "
         "collectives from the kv-expand of a sequence-sharded cache). The "
         "cell-B fix must transfer: predict collective term -99%+ and "
         "memory toward the cache read floor.",
         {"decode_grouped": True, "decode_slim_mask": True}),
    ]),
]


def _raw_terms(rec):
    return rec["roofline"]


def _deltas(base, after):
    return {k: (after[k] / base[k] - 1.0) * 100.0
            for k in ("compute_s", "memory_s", "collective_s")
            if base.get(k, 0) > 0}


def run_plan(cell_id: str):
    plan = next(p for p in PLANS if p[0] == cell_id)
    _, arch, shape_name, steps = plan
    shape = SHAPES[shape_name]
    base_rec = dryrun.run_cell(arch, shape_name, "single_pod")
    base = base_rec["calibrated"]["roofline"]
    base_raw = _raw_terms(base_rec)
    log = {"cell": cell_id, "arch": arch, "shape": shape_name,
           "baseline": base, "baseline_raw": base_raw, "iterations": []}
    print(f"[{cell_id}] baseline {arch} x {shape_name}: "
          f"c/m/x = {base['compute_s']:.3e}/{base['memory_s']:.3e}/"
          f"{base['collective_s']:.3e} bound={base['bottleneck']}")
    for tag, hypothesis, overrides in steps:
        run = RunConfig(seq_len=shape.seq_len,
                        global_batch=shape.global_batch, **overrides)
        rec = dryrun.run_cell(arch, shape_name, "single_pod", run=run,
                              tag=tag, force=False)
        if "error" in rec:
            log["iterations"].append({"tag": tag, "hypothesis": hypothesis,
                                      "error": rec["error"]})
            print(f"[{cell_id}/{tag}] FAILED: {rec['error']}")
            continue
        after = rec["calibrated"]["roofline"]
        after_raw = _raw_terms(rec)
        deltas = _deltas(base, after)
        deltas_raw = _deltas(base_raw, after_raw)
        dom = base["bottleneck"] + "_s"
        # remat-style changes are CSE'd away in the unrolled calibration
        # variants: judge those on the raw scanned terms instead
        use_raw = "remat" in str(overrides)
        dd = deltas_raw if use_raw else deltas
        dom_delta = dd.get(dom, 0.0)
        verdict = "confirmed" if dom_delta < -5.0 else (
            "partial" if dom_delta < 0 else "refuted")
        log["iterations"].append({
            "tag": tag, "hypothesis": hypothesis, "overrides": overrides,
            "after": after, "after_raw": after_raw, "delta_pct": deltas,
            "delta_raw_pct": deltas_raw, "judged_on":
                "raw" if use_raw else "calibrated",
            "dominant_term_delta_pct": dom_delta, "verdict": verdict,
        })
        print(f"[{cell_id}/{tag}] c/m/x = {after['compute_s']:.3e}/"
              f"{after['memory_s']:.3e}/{after['collective_s']:.3e} "
              f"dominant({base['bottleneck']}) {dom_delta:+.1f}% "
              f"-> {verdict}")
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    (PERF_DIR / f"cell_{cell_id}.json").write_text(json.dumps(log, indent=1))
    return log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "D", "E", "all"])
    args = ap.parse_args()
    cells = ["A", "B", "C", "D", "E"] if args.cell == "all" else [args.cell]
    for c in cells:
        run_plan(c)


if __name__ == "__main__":
    main()
