"""Serving launcher: SMS-scheduled multi-tenant engine.

  PYTHONPATH=src python -m repro.launch.serve --scheduler sms [--horizon 4000]

Runs the heterogeneous-client workload (4 interactive + 1 bulk tenant)
through the continuous-batching engine under the chosen scheduler and
prints per-client slowdowns — the serving analogue of the paper's Fig 4.
Use examples/serve_heterogeneous.py for the real-model (paged Pallas) path.
"""
from __future__ import annotations

import argparse

from repro.serving.engine import EngineConfig, fairness_report
from repro.serving.scheduler import SCHEDULERS
from repro.serving.types import default_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="sms",
                    choices=sorted(SCHEDULERS.keys()))
    ap.add_argument("--horizon", type=float, default=4_000.0,
                    help="workload horizon (engine ms)")
    ap.add_argument("--pages", type=int, default=4096)
    ap.add_argument("--slots", type=int, default=32)
    args = ap.parse_args()

    clients = default_clients()
    cfg = EngineConfig(n_pages=args.pages, max_slots=args.slots)
    r = fairness_report(args.scheduler, clients, horizon_ms=args.horizon,
                        engine_cfg=cfg)
    print(f"[serve] scheduler={args.scheduler} finished="
          f"{r['total_finished']} throughput={r['total_tok_s']:.0f} tok/s")
    print(f"[serve] {'client':8s} {'n':>5s} {'mean_ms':>9s} {'p99_ms':>9s} "
          f"{'slowdown':>9s}")
    for spec in clients:
        s = r["clients"].get(spec.name)
        if not s:
            continue
        sd = r["slowdowns"].get(spec.name, float("nan"))
        print(f"[serve] {spec.name:8s} {s['n']:5d} "
              f"{s['mean_latency_ms']:9.1f} {s['p99_latency_ms']:9.1f} "
              f"{sd:9.2f}")
    print(f"[serve] max slowdown: {r['max_slowdown']:.2f}")


if __name__ == "__main__":
    main()
