import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: every cell must
``.lower().compile()`` on the single-pod (16,16)=256-chip mesh and the
multi-pod (2,16,16)=512-chip mesh. Records memory_analysis / cost_analysis /
parsed collective bytes to JSON for §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, RunConfig, shape_cells
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.registry import get_model, input_specs
from repro.optim import adamw
from repro.roofline import analysis as roofline
from repro.train import steps as steps_lib

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _jsonable(d):
    if isinstance(d, dict):
        return {k: _jsonable(v) for k, v in d.items()}
    if isinstance(d, (list, tuple)):
        return [_jsonable(v) for v in d]
    if hasattr(d, "item"):
        return d.item()
    return d


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run: RunConfig | None = None,
               n_layers_override: int | None = None):
    """Lower+compile one cell; returns the record dict.

    n_layers_override: calibration mode — a small UNROLLED variant. XLA's
    cost_analysis counts a while-loop (lax.scan) body once regardless of trip
    count, so per-layer costs are measured from unrolled L=1 and L=3 variants
    and extrapolated to full depth (see run_cell).
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = run or RunConfig(seq_len=shape.seq_len, global_batch=shape.global_batch)
    if n_layers_override is not None:
        kw = {"n_layers": n_layers_override}
        if cfg.is_encoder_decoder:
            kw["n_encoder_layers"] = n_layers_override
        cfg = _dc.replace(cfg, **kw)
        run = run.replace(scan_layers=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = get_model(cfg)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step, in_sh = steps_lib.build_train_step(cfg, run, mesh, shape)
            specs = input_specs(cfg, shape)
            abstract = bundle.abstract_params(
                jnp.bfloat16 if run.param_dtype_bf16 else jnp.float32)
            opt_abs = adamw.AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                mu=abstract, nu=abstract)
            err_abs = abstract if run.grad_compression == "topk" \
                else jax.ShapeDtypeStruct((), jnp.float32)
            args = (abstract, opt_abs, err_abs, specs["batch"],
                    jax.ShapeDtypeStruct((), jnp.int32))
            lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        elif shape.kind == "prefill":
            step, in_sh = steps_lib.build_prefill_step(cfg, run, mesh, shape)
            specs = input_specs(cfg, shape)
            abstract = bundle.abstract_params(jnp.bfloat16)
            args = [abstract, specs["tokens"]]
            if "extra" in specs:
                args.append(specs["extra"])
            lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        else:  # decode
            step, in_sh = steps_lib.build_decode_step(cfg, run, mesh, shape)
            specs = input_specs(cfg, shape)
            abstract = bundle.abstract_params(jnp.bfloat16)
            args = (abstract, specs["cache"], specs["token"], specs["pos"])
            lowered = jax.jit(step, in_shardings=in_sh).lower(*args)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = dict(compiled.cost_analysis() or {})
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not support it
        mem_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    n_coll = sum(1 for _ in roofline._COLL_RE.finditer(hlo))

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    terms = roofline.roofline_terms(flops, bytes_acc, coll_total)

    n_chips = 512 if multi_pod else 256
    mf = roofline.model_flops(
        cfg.n_active_params(),
        shape.tokens if shape.kind == "train" else
        (shape.tokens if shape.kind == "prefill" else shape.global_batch),
        "train" if shape.kind == "train" else "serve")

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "n_chips": n_chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_rec,
        "collective_bytes": coll,
        "n_collective_ops": n_coll,
        "roofline": terms,
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    return rec


def cell_path(arch: str, shape_name: str, mesh_name: str) -> Path:
    return OUT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"


def _calibrate(arch: str, shape_name: str, multi_pod: bool,
               run: RunConfig | None, full_L: int):
    """Per-layer cost extrapolation from unrolled L=1 / L=3 variants."""
    c1 = lower_cell(arch, shape_name, multi_pod, run=run, n_layers_override=1)
    c3 = lower_cell(arch, shape_name, multi_pod, run=run, n_layers_override=3)

    def field(rec, k):
        return float(rec["cost_analysis"].get(k, 0.0))

    out = {}
    for k in ("flops", "bytes accessed"):
        per_layer = (field(c3, k) - field(c1, k)) / 2.0
        out[k] = field(c1, k) + (full_L - 1) * per_layer
    coll = {}
    kinds = set(c1["collective_bytes"]) | set(c3["collective_bytes"])
    for kind in kinds:
        b1 = c1["collective_bytes"].get(kind, 0)
        b3 = c3["collective_bytes"].get(kind, 0)
        coll[kind] = b1 + (full_L - 1) * (b3 - b1) / 2.0
    out["collective_bytes"] = coll
    out["n_collective_ops"] = int(
        c1["n_collective_ops"] + (full_L - 1) *
        (c3["n_collective_ops"] - c1["n_collective_ops"]) / 2.0)
    out["roofline"] = roofline.roofline_terms(
        out["flops"], out["bytes accessed"], sum(coll.values()))
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str, force: bool = False,
             run: RunConfig | None = None, tag: str = "",
             calibrate: bool = True):
    path = cell_path(arch, shape_name, mesh_name + (f"__{tag}" if tag else ""))
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if "error" in rec or "calibrated" in rec or not calibrate:
            print(f"[skip] {path.name} (cached)")
            return rec
    t0 = time.time()
    try:
        rec = lower_cell(arch, shape_name, mesh_name == "multi_pod", run=run)
        rec["tag"] = tag
        cfg = get_config(arch)
        if calibrate and cfg.family != "ssm":
            rec["calibrated"] = _calibrate(
                arch, shape_name, mesh_name == "multi_pod", run,
                cfg.n_layers)
        else:
            # xlstm runs an unrolled python loop: raw numbers are exact
            rec["calibrated"] = {
                "flops": rec["cost_analysis"].get("flops", 0.0),
                "bytes accessed": rec["cost_analysis"].get(
                    "bytes accessed", 0.0),
                "collective_bytes": rec["collective_bytes"],
                "n_collective_ops": rec["n_collective_ops"],
                "roofline": rec["roofline"],
            }
        cal = rec["calibrated"]
        n_chips = rec["n_chips"]
        cal["useful_flops_ratio"] = (
            rec["model_flops_per_chip"] / cal["flops"]
            if cal.get("flops") else None)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(_jsonable(rec), indent=1))
        r = rec["roofline"]
        print(f"[ok] {arch} x {shape_name} x {mesh_name}"
              f" compile={rec['compile_s']:.0f}s"
              f" bound={r['bottleneck']}"
              f" terms(c/m/x)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
              f"{r['collective_s']:.2e}s")
        return rec
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(rec, indent=1))
        print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: "
              f"{type(e).__name__}: {e} ({time.time()-t0:.0f}s)")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ["single_pod", "multi_pod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all or args.arch is None:
        for arch in ARCH_IDS:
            for shape_name in shape_cells(arch):
                for m in meshes:
                    cells.append((arch, shape_name, m))
    else:
        shapes = [args.shape] if args.shape else list(shape_cells(args.arch))
        cells = [(args.arch, s, m) for s in shapes for m in meshes]

    ok = fail = 0
    for arch, shape_name, m in cells:
        rec = run_cell(arch, shape_name, m, force=args.force)
        if "error" in rec:
            fail += 1
        else:
            ok += 1
    print(f"\ndry-run complete: {ok} ok, {fail} failed, "
          f"{len(cells)} cells")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
