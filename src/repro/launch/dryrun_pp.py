import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pipeline-parallel dry-run: GPipe over the `pod` axis at 512 chips.

Proves the third parallelism dimension composes: stages on pods (lowest
bisection bandwidth <- lowest comms), TP over `model`, DP over `data`,
microbatched fill-drain schedule, full backward through the ppermutes.

  PYTHONPATH=src python -m repro.launch.dryrun_pp [--arch gemma2-2b]
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, RunConfig
from repro.configs.registry import get_config
from repro.distributed import sharding as shlib
from repro.distributed.pipeline import gpipe_apply, split_layers_to_stages
from repro.launch.mesh import make_production_mesh
from repro.models import lm as lm_lib
from repro.models.common import abstract_params, param_axes, rms_norm, \
    softmax_xent, stack_defs
from repro.models.registry import get_model
from repro.roofline import analysis as roofline

OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--n-micro", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES["train_4k"]
    run = RunConfig(seq_len=shape.seq_len, global_batch=shape.global_batch)
    mesh = make_production_mesh(multi_pod=True)
    n_stages = 2
    assert cfg.n_layers % n_stages == 0
    bundle = get_model(cfg)
    windows = jnp.asarray(lm_lib.layer_windows(cfg)).reshape(n_stages, -1)

    def stage_fn(stage_tree, h):
        p_stack, w_stack = stage_tree

        def body(h, xs):
            p_l, w_l = xs
            h, _, _ = lm_lib.apply_block(p_l, cfg, run, h, window=w_l)
            return h, None

        h, _ = jax.lax.scan(body, h, (p_stack, w_stack))
        return h

    def pp_loss(params, batch):
        x = lm_lib._embed(params, cfg, run, batch)
        staged = split_layers_to_stages(params["blocks"], n_stages)
        x = gpipe_apply(stage_fn, (staged, windows), x, args.n_micro, mesh,
                        axis="pod")
        x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
        logits = lm_lib._unembed(params, cfg, x)
        return softmax_xent(logits, batch["labels"])

    def pp_step(params, batch):
        return jax.value_and_grad(pp_loss)(params, batch)

    abstract = bundle.abstract_params(jnp.float32)
    p_sh = shlib.param_shardings(bundle.axes(), cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}

    t0 = time.time()
    with mesh:
        lowered = jax.jit(pp_step, in_shardings=(p_sh, b_sh)).lower(
            abstract, batch)
        compiled = lowered.compile()
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    n_permute = hlo.count("collective-permute(")
    rec = {
        "arch": args.arch, "mode": "pipeline_pod2_x_tp16_x_dp16",
        "n_chips": 512, "n_stages": n_stages, "n_micro": args.n_micro,
        "compile_s": round(time.time() - t0, 1),
        "flops": cost.get("flops"),
        "collective_bytes": coll,
        "n_collective_permute": n_permute,
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"PP__{args.arch}__train_4k__multi_pod.json").write_text(
        json.dumps(rec, indent=1))
    print(f"[ok] PP dry-run {args.arch}: 2 stages x 16 TP x 16 DP = 512 "
          f"chips, compile {rec['compile_s']}s, "
          f"{n_permute} collective-permutes in HLO")


if __name__ == "__main__":
    main()
