"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 200 \
      [--seq 512 --batch 8 --ckpt /tmp/ckpt --smoke]

Resolves the arch config, builds the local mesh, and runs the fault-tolerant
Trainer (checkpoint/restart, straggler watchdog). On a real TPU slice the
same entry point runs under `jax.distributed.initialize()` with the
production mesh from `repro.launch.mesh`.
"""
from __future__ import annotations

import argparse

from repro.configs.base import RunConfig, ShapeConfig, reduced
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import StragglerPolicy, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "topk"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    run = RunConfig(seq_len=args.seq, global_batch=args.batch, lr=args.lr,
                    warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps,
                    compute_dtype="float32", remat="none",
                    zero1=args.zero1, grad_compression=args.grad_compression)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    trainer = Trainer(cfg, run, make_local_mesh(), shape, ckpt_dir=args.ckpt,
                      ckpt_every=args.ckpt_every,
                      straggler=StragglerPolicy(action="report"))
    print(f"[train] {cfg.name}: {cfg.n_params() / 1e6:.1f}M params, "
          f"{shape.tokens} tokens/step, {args.steps} steps")
    state = trainer.train(args.steps)
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"[train] done at step {state.step}: "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    for e in trainer.events:
        print(f"[train] event: {e}")


if __name__ == "__main__":
    main()
