"""Atomic, async-capable, reshard-on-load checkpointing (no orbax here).

Layout:  <dir>/step_<N>/shard_<host>.npz + manifest.json
Writes go to <dir>/.tmp_step_<N> then `os.rename` (atomic on POSIX), so a
crash mid-save never corrupts the latest checkpoint. Restore accepts a
target sharding tree and `device_put`s each leaf — loading a checkpoint into
a *different* mesh (elastic restart) is therefore the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str | Path, step: int, tree: PyTree,
         host_id: int = 0, extra: Optional[Dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(tmp / f"shard_{host_id}.npz", **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    return final


def save_async(ckpt_dir: str | Path, step: int, tree: PyTree,
               host_id: int = 0, extra: Optional[Dict] = None
               ) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk off-thread."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save,
                         args=(ckpt_dir, step, host_tree, host_id, extra))
    t.start()
    return t


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, target: PyTree,
            shardings: Optional[PyTree] = None, host_id: int = 0
            ) -> Tuple[PyTree, Dict]:
    """Restore into the structure of `target` (+ optional resharding).

    `target` may contain arrays or ShapeDtypeStructs; `shardings` (a matching
    tree of NamedShardings) re-lays the leaves onto the current mesh — the
    elastic-restart path when the mesh changed since the save.
    """
    final = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((final / "manifest.json").read_text())
    with np.load(final / f"shard_{host_id}.npz") as z:
        flat = {k: z[k] for k in z.files}
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(leaves_p))
    out: List[Any] = []
    for (path, leaf), sh in zip(leaves_p, sh_leaves):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = flat[key]
        expect = getattr(leaf, "shape", None)
        if expect is not None and tuple(arr.shape) != tuple(expect):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs target {expect}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def prune_old(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
