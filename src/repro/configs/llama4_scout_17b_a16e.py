"""llama4-scout-17b-a16e — MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
(+ shared expert, per Llama-4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5_120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8_192,
    vocab_size=202_048,
    head_dim=128,
    n_experts=16,
    top_k=1,
    moe_d_ff=8_192,
    shared_expert=True,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
