"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0: xLSTM blocks carry
their own up/down projections (no separate FFN). Every 4th block is sLSTM
(scalar memory, sequential); the rest are mLSTM (matrix memory, parallel).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=192,          # 768 / 4
    mlstm_heads=4,
    slstm_every=4,
    ssm_expand=2,
    tie_embeddings=True,
    source="arXiv:2405.04517; unverified",
)
