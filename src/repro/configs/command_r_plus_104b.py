"""command-r-plus-104b — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12_288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33_792,
    vocab_size=256_000,
    head_dim=128,
    qkv_bias=False,
    parallel_block=True,   # Cohere parallel attention+FFN residual
    rope_theta=75_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
