"""gemma2-2b — local+global alternating, logit softcap [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2_304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9_216,
    vocab_size=256_000,
    head_dim=256,
    layer_pattern="local_global",
    local_window=4_096,
    logit_softcap=30.0,
    attn_softcap=50.0,
    post_norm=True,
    tie_embeddings=True,
    act="gelu",
    source="arXiv:2408.00118; hf",
)
