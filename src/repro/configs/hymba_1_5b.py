"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each block runs attention heads and mamba heads in parallel on the same
input and fuses (mean of normed outputs). Most attention is sliding-window;
1 global layer every 11 (3 global layers total), per the paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1_600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5_504,
    vocab_size=32_001,
    head_dim=64,
    ssm_state=16,
    ssm_expand=2,
    conv_width=4,
    local_window=1_024,
    global_every=11,
    source="arXiv:2411.13676; hf",
)
