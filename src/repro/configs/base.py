"""Config system: model configs, input shapes, mesh/run configs.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``.
``repro.configs.registry`` resolves ``--arch <id>`` strings.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all assigned families.

    Families: dense | moe | ssm | hybrid | vlm | audio.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    o_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0        # gemma2: 30.0 final / 50.0 attn
    attn_softcap: float = 0.0
    local_window: int = 0             # sliding-window size for local layers
    layer_pattern: str = "global"     # "global" | "local_global" | custom csv
    global_every: int = 0             # hymba: 1 global layer every k (else local)
    parallel_block: bool = False      # command-r: x + attn(n(x)) + mlp(n(x))
    post_norm: bool = False           # gemma2 sandwich norms

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # expert FFN width (d_ff used if 0)
    shared_expert: bool = False       # moonlight-style shared expert
    capacity_factor: float = 1.25

    # --- SSM / xLSTM ---
    ssm_state: int = 0                # mamba state size
    conv_width: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0              # xlstm: sLSTM block every k blocks (0=never)
    mlstm_heads: int = 4

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper: 30s of audio -> 1500 frames
    n_mels: int = 128

    # --- VLM ---
    n_image_tokens: int = 0           # stub patch embeddings prepended

    norm_eps: float = 1e-5
    act: str = "silu"                 # silu | gelu
    dtype: str = "bfloat16"
    source: str = ""                  # provenance tag from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        p = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            p += self.vocab_size * d
        per_layer = 0
        # attention (for families that have it)
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
            per_layer += qkv + (self.n_heads * hd) * d
            if self.qkv_bias:
                per_layer += self.n_heads * hd + 2 * self.n_kv_heads * hd
        if self.family == "moe":
            dff = self.moe_d_ff or self.d_ff
            per_layer += self.n_experts * 3 * d * dff + d * self.n_experts
            if self.shared_expert:
                per_layer += 3 * d * self.d_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff  # swiglu: gate, up, down
        if self.family in ("ssm", "hybrid"):
            dinner = self.ssm_expand * d
            per_layer += d * dinner * 2 + dinner * self.conv_width
            per_layer += dinner * self.ssm_state * 2 + dinner * 2  # B,C,dt,D
            per_layer += dinner * d
        if self.family == "ssm" and self.d_ff == 0:
            # xlstm mLSTM block: qkv + igate/fgate + out
            dinner = self.ssm_expand * d
            per_layer += d * dinner * 3 + dinner * 3 + dinner * d
        per_layer += 2 * d  # norms
        p += self.n_layers * per_layer
        if self.is_encoder_decoder:
            enc_layer = 4 * d * d + 3 * d * self.d_ff + 2 * d
            p += self.n_encoder_layers * enc_layer
            p += self.n_layers * (4 * d * d)  # decoder cross-attention
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        if self.family != "moe":
            return self.n_params()
        dff = self.moe_d_ff or self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model * dff
        return self.n_params() - self.n_layers * inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Archs allowed to run long_500k (sub-quadratic by construction).
LONG_CONTEXT_ARCHS = ("xlstm-125m", "hymba-1.5b")


def shape_cells(arch: str) -> Tuple[str, ...]:
    """The assigned (shape) cells for an arch, honoring the long_500k rule."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return tuple(cells)


@dataclass(frozen=True)
class RunConfig:
    """Training/serving run hyperparameters + distribution flags."""

    seq_len: int = 4096
    global_batch: int = 256
    microbatch: int = 0            # 0 = no gradient accumulation
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0

    # distribution
    remat: str = "full"            # "none" | "full" | "dots" (checkpoint policy)
    zero1: bool = False            # shard optimizer state over data axis
    grad_compression: str = "none" # "none" | "topk"
    topk_ratio: float = 0.01
    use_pallas: bool = False       # pallas kernels (TPU only; XLA path on CPU)
    scan_layers: bool = True
    # perf knobs (baseline defaults; see EXPERIMENTS.md §Perf for measured
    # wins — production deployments enable both)
    attn_batch_reshard: bool = False   # reshard batch over (data, model) for
                                       # attention when heads don't divide TP
    decode_grouped: bool = False       # GQA-grouped decode attention (no kv
                                       # expansion -> no KV read amplification)
    decode_cache_anchor: bool = False  # with_sharding_constraint on the
                                       # decode cache update (stops SPMD from
                                       # all-gathering a seq-sharded cache)
    attn_pad_heads: bool = False       # pad q-heads up to a TP multiple so
                                       # attention shards without reshards
                                       # (wastes pad/Hq flops, zero comms)
    decode_slim_mask: bool = False     # single-query decode: the kv_len mask
                                       # subsumes causality; skip the causal
                                       # compare (one less (B,S) mask pass)
    param_dtype_bf16: bool = False     # bf16 master params + moments
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # serving
    page_size: int = 64            # KV page tokens
    max_pages_per_seq: int = 8192

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        name=cfg.name + "-smoke",
    )
    if cfg.family == "moe":
        kw.update(n_experts=min(cfg.n_experts, 4), moe_d_ff=128,
                  top_k=min(cfg.top_k, 2))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=min(cfg.ssm_state or 8, 8))
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=2, encoder_seq=64)
    if cfg.n_image_tokens:
        kw.update(n_image_tokens=16)
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
