"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5-0.5B family; hf].

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2_560,
    n_heads=20,
    n_kv_heads=20,       # MHA (kv == q heads)
    d_ff=6_912,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
