"""whisper-large-v3 — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

32L (decoder) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866; 32 encoder
layers over 1500 audio frames. The conv1d mel frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1_280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5_120,
    vocab_size=51_866,
    head_dim=64,
    is_encoder_decoder=True,
    n_encoder_layers=32,
    encoder_seq=1_500,
    n_mels=128,
    act="gelu",
    source="arXiv:2212.04356; unverified",
)
