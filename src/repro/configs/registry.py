"""--arch <id> registry for the ten assigned architectures."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

ARCH_IDS = (
    "xlstm-125m",
    "command-r-plus-104b",
    "gemma2-2b",
    "qwen1.5-4b",
    "qwen1.5-110b",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "hymba-1.5b",
    "llava-next-mistral-7b",
    "whisper-large-v3",
)

_MODULE = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULE)}")
    return importlib.import_module(_MODULE[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
