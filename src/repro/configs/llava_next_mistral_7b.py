"""llava-next-mistral-7b — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000. The vision tower is a
STUB per the assignment: input_specs() provides precomputed patch embeddings
(anyres: base 576 + up to 4 tiles x 576 = 2880 image tokens) which are
prepended to the text embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    head_dim=128,
    n_image_tokens=2_880,
    rope_theta=1_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
