"""Selective state-space (Mamba/S6) layer, chunked for TPU.

The selective scan is computed chunk-by-chunk under ``lax.scan`` (carrying the
(B, di, N) hidden state) with an associative scan *inside* each chunk — the
standard TPU adaptation: bounded VMEM working set, MXU-aligned chunk matmuls,
linear-time overall.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef

PyTree = Any


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def mamba_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, n = cfg.d_model, d_inner(cfg), cfg.ssm_state
    return {
        "w_in": ParamDef((d, 2 * di), ("embed", "inner")),
        "conv": ParamDef((cfg.conv_width, di), (None, "inner")),
        "w_bcdt": ParamDef((di, 2 * n + 1), ("inner", None)),
        "dt_bias": ParamDef((di,), ("inner",), "zeros"),
        "a_log": ParamDef((di, n), ("inner", None), "ones"),
        "d_skip": ParamDef((di,), ("inner",), "ones"),
        "w_out": ParamDef((di, d), ("inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return y, xp[:, -(W - 1):]


def selective_scan(u, dt, A, Bc, Cc, h0, chunk: int = 256):
    """u: (B,S,di); dt: (B,S,di); A: (di,N); Bc,Cc: (B,S,N); h0: (B,di,N).

    h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t u_t) B_t ;  y_t = h_t · C_t.
    Returns (y (B,S,di), h_final).
    """
    B, S, di = u.shape
    N = A.shape[1]
    pad = (-S) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    uc = u.reshape(B, nc, chunk, di).swapaxes(0, 1)
    dtc = dt.reshape(B, nc, chunk, di).swapaxes(0, 1)
    Bcc = Bc.reshape(B, nc, chunk, N).swapaxes(0, 1)
    Ccc = Cc.reshape(B, nc, chunk, N).swapaxes(0, 1)

    def chunk_step(h, xs):
        ub, dtb, Bb, Cb = xs                       # (B,L,di), (B,L,N)
        da = jnp.exp(dtb[..., None] * A)           # (B,L,di,N) decay
        bx = (dtb * ub)[..., None] * Bb[:, :, None, :]   # (B,L,di,N)

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (da, bx), axis=1)
        hs = a_cum * h[:, None] + b_cum            # (B,L,di,N)
        y = jnp.einsum("bldn,bln->bld", hs, Cb)
        return hs[:, -1], y

    h_fin, ys = jax.lax.scan(chunk_step, h0, (uc, dtc, Bcc, Ccc))
    y = ys.swapaxes(0, 1).reshape(B, Sp, di)[:, :S]
    return y, h_fin


def mamba_mix(p, cfg: ModelConfig, h, state=None):
    """Mamba mixer on normed input h: (B,S,d).

    state: (ssm_h (B,di,N) f32, conv (B,W-1,di)) or None.
    Returns (y (B,S,d), new_state).
    """
    B, S, _ = h.shape
    di, N = d_inner(cfg), cfg.ssm_state
    up = jnp.einsum("bsd,de->bse", h, p["w_in"].astype(h.dtype))
    xi, z = jnp.split(up, 2, axis=-1)
    conv_state = state[1] if state is not None else None
    xc, new_conv = _causal_conv(xi, p["conv"].astype(h.dtype), conv_state)
    xc = jax.nn.silu(xc)
    bcdt = jnp.einsum("bsi,ik->bsk", xc, p["w_bcdt"].astype(h.dtype))
    Bc = bcdt[..., :N].astype(jnp.float32)
    Cc = bcdt[..., N:2 * N].astype(jnp.float32)
    dt = jax.nn.softplus(bcdt[..., 2 * N].astype(jnp.float32)[..., None]
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    h0 = state[0] if state is not None else jnp.zeros((B, di, N), jnp.float32)
    if S == 1 and state is not None:
        da = jnp.exp(dt[:, 0, :, None] * A)
        hs = da * h0 + (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
            * Bc[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", hs, Cc[:, 0])[:, None]
        h_fin = hs
    else:
        y, h_fin = selective_scan(xc.astype(jnp.float32), dt, A, Bc, Cc, h0)
    y = y.astype(h.dtype) + xc * p["d_skip"].astype(h.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(h.dtype)), \
        (h_fin, new_conv)
