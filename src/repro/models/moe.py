"""Mixture-of-Experts layer with expert-parallel execution.

Experts are sharded over the ``model`` mesh axis. Activations are sharded
over the batch (``data``/``pod``) axes and replicated over ``model``, so each
model shard (a) computes the router identically, (b) gathers only the tokens
routed to *its* experts via a capacity-bounded dispatch table, (c) runs its
local experts, and (d) contributes its partial token outputs to a
``psum`` over ``model`` — the same collective a tensor-parallel dense MLP
needs, i.e. EP comes at no extra collective cost in this 2D mesh.

Two implementations:
  * ``moe_apply``        — shard_map EP path (production default).
  * ``moe_apply_einsum`` — one-hot dispatch-einsum reference (Mesh-TF style);
    kept as the naive baseline for the perf hillclimb and for correctness
    cross-checks in tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig, RunConfig
from repro.models.common import ParamDef, act_fn

PyTree = Any


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, dff, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    defs = {
        "router": ParamDef((d, e), ("embed", "experts")),
        "w_gate": ParamDef((e, d, dff), ("experts", "embed", "mlp")),
        "w_up": ParamDef((e, d, dff), ("experts", "embed", "mlp")),
        "w_down": ParamDef((e, dff, d), ("experts", "mlp", "embed")),
    }
    if cfg.shared_expert:
        defs.update({
            "sh_gate": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
            "sh_up": ParamDef((d, cfg.d_ff), ("embed", "mlp")),
            "sh_down": ParamDef((cfg.d_ff, d), ("mlp", "embed")),
        })
    return defs


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def _route(x: jax.Array, router: jax.Array, cfg: ModelConfig):
    """Top-k routing. x: (T, d). Returns (idx (T,k), gate (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(gates_all, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style) + router z-loss
    me = gates_all.mean(0)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = cfg.n_experts * jnp.sum(me * ce)
    aux = aux + 1e-4 * jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return idx, gate, aux


def _dispatch_tables(idx: jax.Array, n_experts: int, capacity: int):
    """Build (E, C) token-slot tables from (T, k) expert assignments.

    Returns token_id (E, C) int32 (-1 = empty), slot_of (T, k) int32
    (position within expert, >= capacity means dropped).
    """
    T, k = idx.shape
    flat = idx.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # pos within expert
    slot = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    tok = jnp.arange(T * k, dtype=jnp.int32) // k
    keep = slot < capacity
    token_id = jnp.full((n_experts, capacity), -1, jnp.int32)
    token_id = token_id.at[
        jnp.where(keep, flat, n_experts),                   # OOB row drops
        jnp.where(keep, slot, 0)].set(tok, mode="drop")
    return token_id, slot.reshape(T, k)


def _expert_ffn(xg: jax.Array, wg, wu, wd, act) -> jax.Array:
    """xg: (E_loc, C, d) -> (E_loc, C, d)."""
    wg, wu, wd = (w.astype(xg.dtype) for w in (wg, wu, wd))
    h = act(jnp.einsum("ecd,edf->ecf", xg, wg)) * jnp.einsum("ecd,edf->ecf", xg, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_local(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
               act, e_lo: jax.Array, n_local: int) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE: x (T, d) replicated router -> partial out for local experts.

    e_lo: first local expert id; n_local: experts owned by this shard.
    Output must be psum-med over the expert-sharding axis by the caller.
    """
    T, d = x.shape
    C = _capacity(T, cfg)
    idx, gate, aux = _route(x, p["router"], cfg)
    token_id, slot = _dispatch_tables(idx, cfg.n_experts, C)
    local_tok = jax.lax.dynamic_slice_in_dim(token_id, e_lo, n_local, 0)  # (E_loc, C)
    wg = jax.lax.dynamic_slice_in_dim(p["w_gate"], e_lo, n_local, 0)
    wu = jax.lax.dynamic_slice_in_dim(p["w_up"], e_lo, n_local, 0)
    wd = jax.lax.dynamic_slice_in_dim(p["w_down"], e_lo, n_local, 0)
    xg = jnp.where((local_tok >= 0)[..., None],
                   x[jnp.clip(local_tok, 0), :], 0.0)       # (E_loc, C, d)
    yg = _expert_ffn(xg.astype(x.dtype), wg, wu, wd, act)   # (E_loc, C, d)
    # combine back: for each (t, k) whose expert is local and slot kept
    out = jnp.zeros((T, d), jnp.float32)
    k = cfg.top_k
    e_flat = idx.reshape(-1)
    s_flat = slot.reshape(-1)
    t_flat = jnp.arange(T * k) // k
    g_flat = gate.reshape(-1)
    is_local = (e_flat >= e_lo) & (e_flat < e_lo + n_local) & (s_flat < C)
    rows = jnp.where(is_local, e_flat - e_lo, 0)
    vals = yg[rows, jnp.where(is_local, s_flat, 0), :]
    vals = jnp.where(is_local[:, None], vals.astype(jnp.float32) * g_flat[:, None], 0.0)
    out = out.at[t_flat].add(vals)
    # aux loss is identical on every shard; divide so psum restores it
    return out.astype(x.dtype), aux


def moe_apply(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig,
              run: RunConfig, mesh=None, batch_axes: Tuple[str, ...] = ("data",)
              ) -> Tuple[jax.Array, jax.Array]:
    """MoE layer. x: (B, S, d) sharded over batch_axes, replicated over model.

    Returns (y (B,S,d), aux_loss scalar).
    """
    B, S, d = x.shape
    act = act_fn(cfg.act)

    if mesh is None or "model" not in getattr(mesh, "axis_names", ()) or \
            mesh.shape.get("model", 1) == 1:
        y2, aux = _moe_local(x.reshape(B * S, d), p, cfg, act, jnp.int32(0),
                             cfg.n_experts)
        y = y2.reshape(B, S, d)
    else:
        tp = mesh.shape["model"]
        n_local = cfg.n_experts // tp
        assert n_local * tp == cfg.n_experts, \
            f"{cfg.n_experts} experts not divisible by model={tp}"
        pspec_x = P(batch_axes, None, None)
        pspec_w3 = P("model", None, None)
        pspec_r = P(None, None)

        def shard_fn(xs, router, wg, wu, wd):
            e_lo = jax.lax.axis_index("model") * n_local
            Bl, Sl, _ = xs.shape
            pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
            y2, aux = _moe_local(xs.reshape(Bl * Sl, d), pl, cfg, act, e_lo,
                                 n_local)
            y2 = jax.lax.psum(y2, "model")
            # aux is identical across `model` shards (same tokens, same
            # router); psum/tp keeps it differentiable (pmin has no VJP).
            # Across data/pod shards tokens differ -> average (the standard
            # per-DP-shard aux-loss semantics).
            aux = jax.lax.psum(aux, "model") / tp
            data_axes = tuple(a for a in mesh.axis_names if a != "model")
            if data_axes:
                aux = jax.lax.pmean(aux, data_axes)
            return y2.reshape(Bl, Sl, d), aux

        y, aux = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(pspec_x, pspec_r, pspec_w3, pspec_w3, pspec_w3),
            out_specs=(pspec_x, P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.shared_expert:
        h = act(jnp.einsum("bsd,df->bsf", x, p["sh_gate"].astype(x.dtype))) * \
            jnp.einsum("bsd,df->bsf", x, p["sh_up"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", h, p["sh_down"].astype(x.dtype))
    return y, aux


def moe_apply_einsum(x: jax.Array, p: Dict[str, jax.Array], cfg: ModelConfig
                     ) -> Tuple[jax.Array, jax.Array]:
    """One-hot dispatch-einsum reference (naive baseline; O(T·E·C·d) dispatch)."""
    B, S, d = x.shape
    T = B * S
    act = act_fn(cfg.act)
    xf = x.reshape(T, d)
    C = _capacity(T, cfg)
    idx, gate, aux = _route(xf, p["router"], cfg)
    token_id, slot = _dispatch_tables(idx, cfg.n_experts, C)
    # dispatch one-hot (T, E, C); gates apply at COMBINE only (the expert
    # nonlinearity must see the raw token)
    k = cfg.top_k
    t_flat = jnp.arange(T * k) // k
    keep = (slot.reshape(-1) < C)
    disp = jnp.zeros((T, cfg.n_experts, C), x.dtype)
    disp = disp.at[t_flat, idx.reshape(-1),
                   jnp.clip(slot.reshape(-1), 0, C - 1)].add(
        jnp.where(keep, 1.0, 0.0).astype(x.dtype))
    comb = jnp.zeros((T, cfg.n_experts, C), x.dtype)
    comb = comb.at[t_flat, idx.reshape(-1),
                   jnp.clip(slot.reshape(-1), 0, C - 1)].add(
        jnp.where(keep, gate.reshape(-1), 0.0).astype(x.dtype))
    xg = jnp.einsum("tec,td->ecd", disp, xf)
    yg = _expert_ffn(xg, p["w_gate"], p["w_up"], p["w_down"], act)
    y = jnp.einsum("tec,ecd->td", comb, yg).reshape(B, S, d)
    if cfg.shared_expert:
        h = act(jnp.einsum("bsd,df->bsf", x, p["sh_gate"].astype(x.dtype))) * \
            jnp.einsum("bsd,df->bsf", x, p["sh_up"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", h, p["sh_down"].astype(x.dtype))
    return y, aux
