"""Hymba: parallel attention + Mamba heads in every block.

Both paths read the same normed input; their normalized outputs are averaged
(β-weighted fusion in the paper; β learned here as per-path RMS gains).
Most attention layers are sliding-window; one in every ``global_every`` is
global — expressed as a per-layer window array so a single scanned block body
serves all layers.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import lm as lm_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (ParamDef, init_params, init_stacked,
                                 rms_norm, scan_or_unroll, softmax_xent,
                                 stack_defs)

PyTree = Any


def block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), ("embed",), "zeros"),
        "ln2": ParamDef((d,), ("embed",), "zeros"),
        "attn": lm_lib.attn_defs(cfg),
        "mamba": ssm_lib.mamba_defs(cfg),
        "fuse_a": ParamDef((d,), ("embed",), "zeros"),
        "fuse_m": ParamDef((d,), ("embed",), "zeros"),
        "mlp": lm_lib.mlp_defs(cfg),
    }


def full_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"lm": lm_lib.lm_defs(cfg),
            "blocks": stack_defs(block_defs(cfg), cfg.n_layers, "layers")}


def init(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    r1, r2 = jax.random.split(rng)
    return {"lm": init_params(r1, lm_lib.lm_defs(cfg), dtype),
            "blocks": init_stacked(r2, block_defs(cfg), cfg.n_layers, dtype)}


def apply_block(p, cfg: ModelConfig, run: RunConfig, x, *, window,
                cache=None, pos=None):
    """cache: dict(k, v, ssm, conv) or None."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    a, new_attn = lm_lib._attn_apply(p["attn"], cfg, h, window=window,
                                     cache=attn_cache, pos=pos, run=run)
    mamba_state = None if cache is None else (cache["ssm"], cache["conv"])
    m, new_mamba = ssm_lib.mamba_mix(p["mamba"], cfg, h, mamba_state)
    fused = 0.5 * (rms_norm(a, p["fuse_a"], cfg.norm_eps) +
                   rms_norm(m, p["fuse_m"], cfg.norm_eps))
    x = x + fused
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + lm_lib._mlp_apply(p["mlp"], cfg, h2)
    new_cache = None
    if cache is not None:
        new_cache = {"k": new_attn["k"], "v": new_attn["v"],
                     "ssm": new_mamba[0], "conv": new_mamba[1]}
    return x, new_cache


def forward_train(params, cfg: ModelConfig, run: RunConfig, batch,
                  mesh=None, batch_axes=("data",)):
    x = params["lm"]["embed"][batch["tokens"]].astype(run.compute_dtype)
    windows = jnp.asarray(lm_lib.layer_windows(cfg))

    def body(x, xs):
        p_l, w_l = xs
        x, _ = apply_block(p_l, cfg, run, x, window=w_l)
        return x, None

    fn = body
    if run.remat != "none":
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_or_unroll(run.scan_layers, fn, x, (params["blocks"], windows))
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm"]["lm_head"].astype(x.dtype)) \
        if not cfg.tie_embeddings else \
        jnp.einsum("bsd,vd->bsv", x, params["lm"]["embed"].astype(x.dtype))
    return logits, jnp.float32(0.0)


def train_loss(params, cfg, run, batch, mesh=None, batch_axes=("data",)):
    logits, _ = forward_train(params, cfg, run, batch, mesh, batch_axes)
    return softmax_xent(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               abstract: bool = False) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    di, N = ssm_lib.d_inner(cfg), cfg.ssm_state
    L = cfg.n_layers
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
        (lambda s, dt: jnp.zeros(s, dt))
    return {"k": mk((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "v": mk((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "ssm": mk((L, batch, di, N), jnp.float32),
            "conv": mk((L, batch, cfg.conv_width - 1, di), dtype)}


def prefill(params, cfg: ModelConfig, run: RunConfig, cache, tokens,
            mesh=None, batch_axes=("data",), extra=None):
    """Full-prompt pass writing KV caches + SSM states. tokens: (B, S)."""
    B, S = tokens.shape
    x = params["lm"]["embed"][tokens].astype(run.compute_dtype)
    windows = jnp.asarray(lm_lib.layer_windows(cfg))
    pos0 = jnp.zeros((B,), jnp.int32)

    def body(x, xs):
        p_l, w_l, cache_l = xs
        x, new_cache_l = apply_block(p_l, cfg, run, x, window=w_l,
                                     cache=cache_l, pos=pos0)
        return x, new_cache_l

    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["blocks"], windows, cache))
    x = rms_norm(x[:, -1:], params["lm"]["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm"]["lm_head"].astype(x.dtype)) \
        if not cfg.tie_embeddings else \
        jnp.einsum("bsd,vd->bsv", x, params["lm"]["embed"].astype(x.dtype))
    return logits[:, 0], new_cache, jnp.full((B,), S, jnp.int32)


def decode_step(params, cfg: ModelConfig, run: RunConfig, cache, token, pos,
                mesh=None, batch_axes=("data",)):
    x = params["lm"]["embed"][token[:, None]].astype(run.compute_dtype)
    windows = jnp.asarray(lm_lib.layer_windows(cfg))

    def body(x, xs):
        p_l, w_l, cache_l = xs
        x, new_cache_l = apply_block(p_l, cfg, run, x, window=w_l,
                                     cache=cache_l, pos=pos)
        return x, new_cache_l

    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["blocks"], windows, cache))
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm"]["lm_head"].astype(x.dtype)) \
        if not cfg.tie_embeddings else \
        jnp.einsum("bsd,vd->bsv", x, params["lm"]["embed"].astype(x.dtype))
    return logits[:, 0], new_cache
