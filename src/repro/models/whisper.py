"""Whisper-style encoder-decoder backbone (conv mel frontend is a STUB).

``input_specs()`` supplies precomputed frame embeddings (B, encoder_seq, d) —
the product of the (stubbed) conv1d mel frontend. Positions are sinusoidal.
Decoder = causal self-attention + cross-attention to the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.common import (ParamDef, act_fn, attention, init_params,
                                 init_stacked, rms_norm, scan_or_unroll,
                                 sinusoidal_positions, softmax_xent,
                                 stack_defs)
from repro.models.lm import _expand_kv, _mlp_apply, attention_with_knobs, \
    mlp_defs

PyTree = Any


def _proj_defs(cfg: ModelConfig, n_kv: int) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, n_kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }


def enc_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": ParamDef((cfg.d_model,), ("embed",), "zeros"),
            "attn": _proj_defs(cfg, cfg.n_kv_heads),
            "ln2": ParamDef((cfg.d_model,), ("embed",), "zeros"),
            "mlp": mlp_defs(cfg)}


def dec_block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": ParamDef((cfg.d_model,), ("embed",), "zeros"),
            "self_attn": _proj_defs(cfg, cfg.n_kv_heads),
            "ln_x": ParamDef((cfg.d_model,), ("embed",), "zeros"),
            "cross_attn": _proj_defs(cfg, cfg.n_kv_heads),
            "ln2": ParamDef((cfg.d_model,), ("embed",), "zeros"),
            "mlp": mlp_defs(cfg)}


def full_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed")),
        "lm_head": ParamDef((d, cfg.vocab_size), ("embed", "vocab")),
        "final_norm": ParamDef((d,), ("embed",), "zeros"),
        "enc_norm": ParamDef((d,), ("embed",), "zeros"),
        "enc": stack_defs(enc_block_defs(cfg), cfg.n_encoder_layers, "layers"),
        "dec": stack_defs(dec_block_defs(cfg), cfg.n_layers, "layers"),
    }


def init(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    r1, r2, r3 = jax.random.split(rng, 3)
    top = {k: v for k, v in full_defs(cfg).items() if k not in ("enc", "dec")}
    out = init_params(r1, top, dtype)
    out["enc"] = init_stacked(r2, enc_block_defs(cfg), cfg.n_encoder_layers, dtype)
    out["dec"] = init_stacked(r3, dec_block_defs(cfg), cfg.n_layers, dtype)
    return out


def _proj_qkv(p, cfg, xq, xkv):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(xq.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(xq.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(xq.dtype))
    return q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads)


def encode(params, cfg: ModelConfig, run: RunConfig, audio_embeds,
           mesh=None, batch_axes=("data",)):
    """audio_embeds: (B, S_enc, d) from the stub frontend."""
    x = audio_embeds.astype(run.compute_dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(p["attn"], cfg, h, h)
        a = attention_with_knobs(q, k, v, n_heads=cfg.n_heads, causal=False,
                                 run=run, mesh=mesh, batch_axes=batch_axes)
        x = x + jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(x.dtype))
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + _mlp_apply(p["mlp"], cfg, h), None

    x, _ = scan_or_unroll(run.scan_layers, body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(p, cfg, run, x, enc_out=None, cache=None, pos=None,
               mesh=None, batch_axes=("data",)):
    """Decoder block; cache: dict(k,v,ck,cv) (cross kv precomputed) or None."""
    B, S, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wq"].astype(h.dtype))
    kh = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wk"].astype(h.dtype))
    vh = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wv"].astype(h.dtype))
    if cache is None:
        a = attention_with_knobs(q, _expand_kv(kh, cfg.n_heads),
                                 _expand_kv(vh, cfg.n_heads),
                                 n_heads=cfg.n_heads, causal=True,
                                 run=run, mesh=mesh, batch_axes=batch_axes)
        new_cache = None
    else:
        positions = pos[:, None] + jnp.arange(S)[None]
        write = (jnp.arange(cache["k"].shape[1])[None, :, None, None]
                 == pos[:, None, None, None])
        ck = jnp.where(write, kh[:, :1].astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(write, vh[:, :1].astype(cache["v"].dtype), cache["v"])
        from repro.models.common import gqa_attention
        a = gqa_attention(q, _expand_kv(ck.astype(h.dtype), cfg.n_heads),
                          _expand_kv(cv.astype(h.dtype), cfg.n_heads),
                          causal=True, q_offset=pos, kv_len=pos + S)
        new_cache = {"k": ck, "v": cv}
    x = x + jnp.einsum("bshk,hkd->bsd", a, p["self_attn"]["wo"].astype(x.dtype))
    # cross attention
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"].astype(h.dtype))
    if cache is None:
        kx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"].astype(h.dtype))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"].astype(h.dtype))
    else:
        kx, vx = cache["ck"].astype(h.dtype), cache["cv"].astype(h.dtype)
        new_cache.update({"ck": cache["ck"], "cv": cache["cv"]})
    if cache is None:
        ax = attention_with_knobs(qx, _expand_kv(kx, cfg.n_heads),
                                  _expand_kv(vx, cfg.n_heads),
                                  n_heads=cfg.n_heads, causal=False,
                                  run=run, mesh=mesh, batch_axes=batch_axes)
    else:
        ax = attention(qx, _expand_kv(kx, cfg.n_heads),
                       _expand_kv(vx, cfg.n_heads), causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", ax, p["cross_attn"]["wo"].astype(x.dtype))
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _mlp_apply(p["mlp"], cfg, h), new_cache


def forward_train(params, cfg: ModelConfig, run: RunConfig, batch,
                  mesh=None, batch_axes=("data",)):
    """batch: audio_embeds (B,S_enc,d), tokens (B,S_dec), labels (B,S_dec)."""
    enc_out = encode(params, cfg, run, batch["audio_embeds"], mesh, batch_axes)
    x = params["embed"][batch["tokens"]].astype(run.compute_dtype)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(x, p):
        x, _ = _dec_block(p, cfg, run, x, enc_out=enc_out, mesh=mesh,
                          batch_axes=batch_axes)
        return x, None

    fn = body
    if run.remat != "none":
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = scan_or_unroll(run.scan_layers, fn, x, params["dec"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, jnp.float32(0.0)


def train_loss(params, cfg, run, batch, mesh=None, batch_axes=("data",)):
    logits, _ = forward_train(params, cfg, run, batch, mesh, batch_axes)
    return softmax_xent(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               abstract: bool = False) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
        (lambda s, dt: jnp.zeros(s, dt))
    return {"k": mk((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "v": mk((L, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "ck": mk((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
            "cv": mk((L, batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype)}


def prefill(params, cfg: ModelConfig, run: RunConfig, cache, tokens,
            mesh=None, batch_axes=("data",), extra=None):
    """Encode audio + run the decoder prompt, writing self- and cross-KV.

    extra: {"audio_embeds": (B, S_enc, d)}.
    """
    B, S = tokens.shape
    enc_out = encode(params, cfg, run, extra["audio_embeds"])
    x = params["embed"][tokens].astype(run.compute_dtype)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    def body(x, xs):
        p_l, cache_l = xs
        # precompute cross kv for this layer
        kx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        p_l["cross_attn"]["wk"].astype(x.dtype))
        vx = jnp.einsum("bsd,dhk->bshk", enc_out,
                        p_l["cross_attn"]["wv"].astype(x.dtype))
        cache_l = dict(cache_l, ck=kx.astype(cache_l["ck"].dtype),
                       cv=vx.astype(cache_l["cv"].dtype))
        # self-attn over full prompt, writing cache at [0, S)
        h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p_l["self_attn"]["wq"].astype(h.dtype))
        kh = jnp.einsum("bsd,dhk->bshk", h, p_l["self_attn"]["wk"].astype(h.dtype))
        vh = jnp.einsum("bsd,dhk->bshk", h, p_l["self_attn"]["wv"].astype(h.dtype))
        a = attention(q, _expand_kv(kh, cfg.n_heads),
                      _expand_kv(vh, cfg.n_heads), causal=True)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], kh.astype(cache_l["k"].dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], vh.astype(cache_l["v"].dtype), 0, axis=1)
        x2 = x + jnp.einsum("bshk,hkd->bsd", a,
                            p_l["self_attn"]["wo"].astype(x.dtype))
        h2 = rms_norm(x2, p_l["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h2,
                        p_l["cross_attn"]["wq"].astype(h2.dtype))
        ax = attention(qx, _expand_kv(kx, cfg.n_heads),
                       _expand_kv(vx, cfg.n_heads), causal=False)
        x2 = x2 + jnp.einsum("bshk,hkd->bsd", ax,
                             p_l["cross_attn"]["wo"].astype(x2.dtype))
        h3 = rms_norm(x2, p_l["ln2"], cfg.norm_eps)
        x2 = x2 + _mlp_apply(p_l["mlp"], cfg, h3)
        return x2, dict(k=ck, v=cv, ck=cache_l["ck"], cv=cache_l["cv"])

    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["dec"], cache))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], new_cache, jnp.full((B,), S, jnp.int32)


def decode_step(params, cfg: ModelConfig, run: RunConfig, cache, token, pos,
                mesh=None, batch_axes=("data",)):
    x = params["embed"][token[:, None]].astype(run.compute_dtype)
    # per-position sinusoid
    sin_table = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + sin_table[pos][:, None].astype(x.dtype)

    def body(x, xs):
        p_l, cache_l = xs
        x, new_cache_l = _dec_block(p_l, cfg, run, x, cache=cache_l, pos=pos)
        return x, new_cache_l

    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["dec"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], new_cache
