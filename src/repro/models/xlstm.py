"""xLSTM: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scalar memory).

TPU adaptation: the mLSTM is computed in *chunkwise-parallel* form — within a
chunk a masked attention-like matmul (MXU-friendly), across chunks a
`lax.scan` carrying the stabilized (C, n, m) state — giving O(S·L_c) compute
instead of the O(S^2) fully-parallel form. The sLSTM has a true sequential
dependency (recurrent gate matmuls) and runs as a per-timestep scan, exactly
as the paper concedes.

State is stabilized in log space: the carried (C̄, n̄) have the running max m
factored out (true C = C̄·e^m), matching the paper's Appendix stabilization.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.common import (ParamDef, init_params, rms_norm,
                                 softmax_xent)

PyTree = Any

NEG = -1e30


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def mlstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, di, h = cfg.d_model, _d_inner(cfg), cfg.mlstm_heads
    dh = di // h
    return {
        "ln": ParamDef((d,), ("embed",), "zeros"),
        "w_up": ParamDef((d, 2 * di), ("embed", "inner")),
        "conv": ParamDef((cfg.conv_width, di), (None, "inner")),
        "wq": ParamDef((di, h, dh), ("inner", None, None)),
        "wk": ParamDef((di, h, dh), ("inner", None, None)),
        "wv": ParamDef((di, h, dh), ("inner", None, None)),
        "w_if": ParamDef((di, h, 2), ("inner", None, None), scale=0.1),
        "b_if": ParamDef((h, 2), (None, None), "zeros"),
        "gn": ParamDef((di,), ("inner",), "zeros"),
        "w_down": ParamDef((di, d), ("inner", "embed")),
    }


def slstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h = cfg.d_model, cfg.mlstm_heads
    dh = d // h
    return {
        "ln": ParamDef((d,), ("embed",), "zeros"),
        "wx": ParamDef((d, h, 4, dh), ("embed", None, None, None)),
        "wr": ParamDef((h, dh, 4, dh), (None, None, None, None), scale=0.5),
        "b": ParamDef((h, 4, dh), (None, None, None), "zeros"),
        "wz_gate": ParamDef((d, d), ("embed", None)),
        "gn": ParamDef((d,), ("embed",), "zeros"),
        "w_down": ParamDef((d, d), ("embed", "embed")),
    }


def is_slstm(cfg: ModelConfig, i: int) -> bool:
    return bool(cfg.slstm_every) and (i % cfg.slstm_every == 0)


def full_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "final_norm": ParamDef((cfg.d_model,), ("embed",), "zeros"),
        "blocks": [slstm_defs(cfg) if is_slstm(cfg, i) else mlstm_defs(cfg)
                   for i in range(cfg.n_layers)],
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"))
    return defs


def init(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    return init_params(rng, full_defs(cfg), dtype)


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """x: (B,S,di); w: (W,di). Depthwise causal conv. Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return y, xp[:, -(W - 1):] if W > 1 else state


def mlstm_chunk_scan(q, k, v, lf, li, state, chunk: int = 256):
    """Chunkwise-parallel mLSTM.

    q,k,v: (B,H,S,dh); lf: (B,H,S) log-forget (<=0); li: (B,H,S) log-input.
    state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)) with true C = C̄·e^m.
    Returns (h (B,H,S,dh), new_state).
    """
    B, H, S, dh = q.shape
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=NEG)
    Sp = S + pad
    nc = Sp // chunk
    rs = lambda a: a.reshape(B, H, nc, chunk, *a.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # -> (nc, B, H, chunk, ...)
    # scale q once so intra-chunk AND carried-state terms are consistent
    qs, ks, vs = rs(q * dh ** -0.5), rs(k), rs(v)
    lfs = lf.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
    lis = li.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    def step(carry, xs):
        C, n, m = carry          # C: (B,H,dh,dh) with true value C*e^m
        qc, kc, vc, lfc, lic = xs
        F = jnp.cumsum(lfc, axis=-1)                       # (B,H,L)
        # log weight of input j as seen at position i: F_i - F_j + li_j
        dlog = F[..., :, None] - F[..., None, :] + lic[..., None, :]
        iidx = jnp.arange(chunk)
        dlog = jnp.where(iidx[:, None] >= iidx[None, :], dlog, NEG)
        state_log = F + m[..., None]                       # (B,H,L)
        m_i = jnp.maximum(dlog.max(-1), state_log)
        m_i = jnp.maximum(m_i, -40.0)                      # avoid -inf carries
        w = jnp.exp(dlog - m_i[..., None])                 # (B,H,L,L)
        sqk = jnp.einsum("bhid,bhjd->bhij", qc, kc)
        num_intra = jnp.einsum("bhij,bhjd->bhid", w * sqk, vc)
        den_intra = jnp.einsum("bhij,bhij->bhi", w, sqk)
        sfac = jnp.exp(state_log - m_i)                    # (B,H,L)
        num_state = jnp.einsum("bhid,bhde->bhie", qc, C) * sfac[..., None]
        den_state = jnp.einsum("bhid,bhd->bhi", qc, n) * sfac
        num = num_intra + num_state
        den = den_intra + den_state
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # end-of-chunk state
        FL = F[..., -1:]                                   # (B,H,1)
        m_new = jnp.maximum(FL[..., 0] + m,
                            (FL - F + lic).max(-1))
        m_new = jnp.maximum(m_new, -40.0)
        wL = jnp.exp(FL - F + lic - m_new[..., None])      # (B,H,L)
        C_new = jnp.exp(FL[..., 0] + m - m_new)[..., None, None] * C + \
            jnp.einsum("bhj,bhjd,bhje->bhde", wL, kc, vc)
        n_new = jnp.exp(FL[..., 0] + m - m_new)[..., None] * n + \
            jnp.einsum("bhj,bhjd->bhd", wL, kc)
        return (C_new, n_new, m_new), h

    state2, hs = jax.lax.scan(step, state, (qs, ks, vs, lfs, lis))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sp, dh)[:, :, :S]
    return h, state2


def mlstm_decode_cell(q, k, v, lf, li, state):
    """Single-token mLSTM update. q,k,v: (B,H,dh); lf,li: (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(lf + m, li)
    fprime = jnp.exp(lf + m - m_new)
    iprime = jnp.exp(li - m_new)
    C_new = fprime[..., None, None] * C + \
        iprime[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = fprime[..., None] * n + iprime[..., None] * k
    scale = q.shape[-1] ** -0.5
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C_new)
    den = jnp.einsum("bhd,bhd->bh", q * scale, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C_new, n_new, m_new)


def _mlstm_qkvg(p, cfg, h, conv_state=None):
    """Shared projections. h: (B,S,d) normed input."""
    B, S, _ = h.shape
    di, H = _d_inner(cfg), cfg.mlstm_heads
    dh = di // H
    up = jnp.einsum("bsd,de->bse", h, p["w_up"].astype(h.dtype))
    xm, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = _causal_conv(xm, p["conv"].astype(h.dtype), conv_state)
    xc = jax.nn.silu(xc)
    q = jnp.einsum("bsi,ihd->bhsd", xc, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsi,ihd->bhsd", xc, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsi,ihd->bhsd", xm, p["wv"].astype(h.dtype))
    gates = jnp.einsum("bsi,ihg->bhsg", xm, p["w_if"].astype(h.dtype)) + \
        p["b_if"].astype(h.dtype)[None, :, None, :]
    li = gates[..., 0].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(gates[..., 1].astype(jnp.float32) + 3.0)
    return q, k, v, lf, li, z, new_conv


def _mlstm_out(p, cfg, hcell, z, x):
    """hcell: (B,H,S,dh) -> residual output."""
    B, H, S, dh = hcell.shape
    hflat = hcell.transpose(0, 2, 1, 3).reshape(B, S, H * dh)
    hflat = rms_norm(hflat.astype(z.dtype), p["gn"], cfg.norm_eps)
    y = hflat * jax.nn.silu(z)
    return x + jnp.einsum("bsi,id->bsd", y, p["w_down"].astype(z.dtype))


def mlstm_block(p, cfg: ModelConfig, x, state=None):
    """x: (B,S,d). state: (C,n,m,conv) or None (train). Returns (x, state)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    conv_state = state[3] if state is not None else None
    q, k, v, lf, li, z, new_conv = _mlstm_qkvg(p, cfg, h, conv_state)
    B = x.shape[0]
    H = cfg.mlstm_heads
    dh = _d_inner(cfg) // H
    if state is None:
        s0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
              jnp.zeros((B, H, dh), jnp.float32),
              jnp.full((B, H), -40.0, jnp.float32))
        hcell, s_fin = mlstm_chunk_scan(q.astype(jnp.float32),
                                        k.astype(jnp.float32),
                                        v.astype(jnp.float32), lf, li, s0)
        return _mlstm_out(p, cfg, hcell.astype(x.dtype), z, x), \
            (s_fin[0], s_fin[1], s_fin[2], new_conv)
    C, n, m = state[0], state[1], state[2]
    hc, (C2, n2, m2) = mlstm_decode_cell(
        q[:, :, 0].astype(jnp.float32), k[:, :, 0].astype(jnp.float32),
        v[:, :, 0].astype(jnp.float32), lf[:, :, 0], li[:, :, 0], (C, n, m))
    hcell = hc[:, :, None, :]
    y = _mlstm_out(p, cfg, hcell.astype(x.dtype), z, x)
    return y, (C2, n2, m2, new_conv)


# ---------------------------------------------------------------------------
# sLSTM — sequential scan
# ---------------------------------------------------------------------------

def slstm_cell(p, cfg, xt, state):
    """One sLSTM step. xt: (B,H,4,dh) pre-activations from W·x_t.

    state: (c, n, h, m) each (B,H,dh).
    """
    c, n, h, m = state
    rec = jnp.einsum("bhd,hdge->bhge", h, p["wr"].astype(h.dtype))
    pre = xt + rec + p["b"].astype(h.dtype)[None]
    zt = jnp.tanh(pre[:, :, 0].astype(jnp.float32))
    it = pre[:, :, 1].astype(jnp.float32)
    ft = jax.nn.log_sigmoid(pre[:, :, 2].astype(jnp.float32) + 3.0)
    ot = jax.nn.sigmoid(pre[:, :, 3].astype(jnp.float32))
    m_new = jnp.maximum(ft + m, it)
    iprime = jnp.exp(it - m_new)
    fprime = jnp.exp(ft + m - m_new)
    c_new = fprime * c + iprime * zt
    n_new = fprime * n + iprime
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new.astype(h.dtype), m_new), h_new


def slstm_block(p, cfg: ModelConfig, x, state=None):
    """x: (B,S,d). Returns (x_out, state)."""
    B, S, d = x.shape
    H = cfg.mlstm_heads
    dh = d // H
    hin = rms_norm(x, p["ln"], cfg.norm_eps)
    xw = jnp.einsum("bsd,dhge->bshge", hin, p["wx"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", hin, p["wz_gate"].astype(x.dtype))
    if state is None:
        zero = jnp.zeros((B, H, dh), jnp.float32)
        state = (zero, zero, jnp.zeros((B, H, dh), x.dtype),
                 jnp.full((B, H, dh), -40.0, jnp.float32))

    def step(carry, xt):
        carry, h = slstm_cell(p, cfg, xt, carry)
        return carry, h

    state2, hs = jax.lax.scan(step, state, xw.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)   # (B,S,H,dh)->(B,S,d)
    hs = rms_norm(hs, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
    y = x + jnp.einsum("bsd,de->bse", hs, p["w_down"].astype(x.dtype))
    return y, state2


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, run: RunConfig, batch,
                  mesh=None, batch_axes=("data",)):
    x = params["embed"][batch["tokens"]].astype(run.compute_dtype)
    for i in range(cfg.n_layers):
        p = params["blocks"][i]
        blk = slstm_block if is_slstm(cfg, i) else mlstm_block
        if run.remat != "none":
            x, _ = jax.checkpoint(lambda p_, x_, b=blk: b(p_, cfg, x_))(p, x)
        else:
            x, _ = blk(p, cfg, x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, jnp.float32(0.0)


def train_loss(params, cfg, run, batch, mesh=None, batch_axes=("data",)):
    logits, _ = forward_train(params, cfg, run, batch, mesh, batch_axes)
    return softmax_xent(logits, batch["labels"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               abstract: bool = False) -> List:
    """Recurrent state per layer (no KV pages — O(1) in seq length)."""
    di, H = _d_inner(cfg), cfg.mlstm_heads
    dh_m = di // H
    dh_s = cfg.d_model // H
    mk = (lambda s, dt: jax.ShapeDtypeStruct(s, dt)) if abstract else \
        (lambda s, dt: jnp.zeros(s, dt))
    cache = []
    for i in range(cfg.n_layers):
        if is_slstm(cfg, i):
            cache.append((mk((batch, H, dh_s), jnp.float32),
                          mk((batch, H, dh_s), jnp.float32),
                          mk((batch, H, dh_s), dtype),
                          mk((batch, H, dh_s), jnp.float32)))
        else:
            cache.append((mk((batch, H, dh_m, dh_m), jnp.float32),
                          mk((batch, H, dh_m), jnp.float32),
                          mk((batch, H), jnp.float32),
                          mk((batch, cfg.conv_width - 1, di), dtype)))
    return cache


def prefill(params, cfg: ModelConfig, run: RunConfig, cache, tokens,
            mesh=None, batch_axes=("data",), extra=None):
    """Process the prompt, returning last-token logits + recurrent states."""
    del cache  # states are created fresh (O(1) in prompt length)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(run.compute_dtype)
    new_cache = []
    for i in range(cfg.n_layers):
        p = params["blocks"][i]
        blk = slstm_block if is_slstm(cfg, i) else mlstm_block
        x, st = blk(p, cfg, x)
        new_cache.append(st)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], new_cache, jnp.full((B,), S, jnp.int32)


def decode_step(params, cfg: ModelConfig, run: RunConfig, cache, token, pos,
                mesh=None, batch_axes=("data",)):
    x = params["embed"][token[:, None]].astype(run.compute_dtype)
    new_cache = []
    for i in range(cfg.n_layers):
        p = params["blocks"][i]
        if is_slstm(cfg, i):
            B, S, d = x.shape
            H, dh = cfg.mlstm_heads, cfg.d_model // cfg.mlstm_heads
            hin = rms_norm(x, p["ln"], cfg.norm_eps)
            xw = jnp.einsum("bsd,dhge->bshge", hin, p["wx"].astype(x.dtype))
            z = jnp.einsum("bsd,de->bse", hin, p["wz_gate"].astype(x.dtype))
            st, h = slstm_cell(p, cfg, xw[:, 0], cache[i])
            hs = h.reshape(B, 1, d).astype(x.dtype)
            hs = rms_norm(hs, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
            x = x + jnp.einsum("bsd,de->bse", hs, p["w_down"].astype(x.dtype))
            new_cache.append(st)
        else:
            x, st = mlstm_block(p, cfg, x, cache[i])
            new_cache.append(st)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], new_cache
