"""Uniform model interface: init / train_loss / prefill / decode_step / specs.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every input of the lowered step — the dry-run lowers against
these without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import hymba as hymba_lib
from repro.models import lm as lm_lib
from repro.models import whisper as whisper_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import abstract_params, param_axes

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[..., PyTree]
    full_defs: Callable[[], PyTree]
    train_loss: Callable[..., jax.Array]
    init_cache: Callable[..., PyTree]
    prefill: Optional[Callable[..., Any]]
    decode_step: Callable[..., Any]

    def abstract_params(self, dtype=jnp.float32) -> PyTree:
        return abstract_params(self.full_defs(), dtype)

    def axes(self) -> PyTree:
        return param_axes(self.full_defs())


def get_model(cfg: ModelConfig) -> ModelBundle:
    fam = cfg.family
    if fam == "ssm":
        lib = xlstm_lib
        return ModelBundle(
            cfg=cfg,
            init=lambda rng, dtype=jnp.float32: lib.init(rng, cfg, dtype),
            full_defs=lambda: lib.full_defs(cfg),
            train_loss=lambda p, run, batch, **kw: lib.train_loss(p, cfg, run, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16, abstract=False:
                lib.init_cache(cfg, batch, max_seq, dtype, abstract),
            prefill=lambda p, run, cache, tokens, **kw:
                lib.prefill(p, cfg, run, cache, tokens, **kw),
            decode_step=lambda p, run, cache, token, pos, **kw:
                lib.decode_step(p, cfg, run, cache, token, pos, **kw),
        )
    if fam == "hybrid":
        lib = hymba_lib
        return ModelBundle(
            cfg=cfg,
            init=lambda rng, dtype=jnp.float32: lib.init(rng, cfg, dtype),
            full_defs=lambda: lib.full_defs(cfg),
            train_loss=lambda p, run, batch, **kw: lib.train_loss(p, cfg, run, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16, abstract=False:
                lib.init_cache(cfg, batch, max_seq, dtype, abstract),
            prefill=lambda p, run, cache, tokens, **kw:
                lib.prefill(p, cfg, run, cache, tokens, **kw),
            decode_step=lambda p, run, cache, token, pos, **kw:
                lib.decode_step(p, cfg, run, cache, token, pos, **kw),
        )
    if fam == "audio":
        lib = whisper_lib
        return ModelBundle(
            cfg=cfg,
            init=lambda rng, dtype=jnp.float32: lib.init(rng, cfg, dtype),
            full_defs=lambda: lib.full_defs(cfg),
            train_loss=lambda p, run, batch, **kw: lib.train_loss(p, cfg, run, batch, **kw),
            init_cache=lambda batch, max_seq, dtype=jnp.bfloat16, abstract=False:
                lib.init_cache(cfg, batch, max_seq, dtype, abstract),
            prefill=lambda p, run, cache, tokens, **kw:
                lib.prefill(p, cfg, run, cache, tokens, **kw),
            decode_step=lambda p, run, cache, token, pos, **kw:
                lib.decode_step(p, cfg, run, cache, token, pos, **kw),
        )
    # dense / moe / vlm
    lib = lm_lib

    def _cache(batch, max_seq, dtype=jnp.bfloat16, abstract=False):
        return (lib.abstract_cache if abstract else lib.init_cache)(
            cfg, batch, max_seq, dtype)

    return ModelBundle(
        cfg=cfg,
        init=lambda rng, dtype=jnp.float32: lib.init(rng, cfg, dtype),
        full_defs=lambda: lib.full_defs(cfg),
        train_loss=lambda p, run, batch, **kw: lib.train_loss(p, cfg, run, batch, **kw),
        init_cache=_cache,
        prefill=lambda p, run, cache, tokens, **kw:
            lib.prefill(p, cfg, run, cache, tokens, **kw),
        decode_step=lambda p, run, cache, token, pos, **kw:
            lib.decode_step(p, cfg, run, cache, token, pos, **kw),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the lowered step's data inputs.

    train/prefill: token batch (+ stub modality embeddings).
    decode: one new token + per-seq position + the KV cache/state.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if shape.kind == "train":
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            s_text = S - cfg.n_image_tokens
            batch["tokens"] = tok((B, s_text))
            batch["labels"] = tok((B, s_text))
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), compute_dtype)
        elif cfg.family == "audio":
            batch["tokens"] = tok((B, S))
            batch["labels"] = tok((B, S))
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), compute_dtype)
        else:
            batch["tokens"] = tok((B, S))
            batch["labels"] = tok((B, S))
        return {"batch": batch}

    if shape.kind == "prefill":
        spec: Dict[str, Any] = {"tokens": tok((B, S))}
        if cfg.family == "vlm":
            spec["tokens"] = tok((B, S - cfg.n_image_tokens))
            spec["extra"] = {"image_embeds": jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), compute_dtype)}
        if cfg.family == "audio":
            spec["extra"] = {"audio_embeds": jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), compute_dtype)}
        return spec

    # decode: one token with a cache of S
    from repro.models.registry import get_model  # self-import ok
    bundle = get_model(cfg)
    cache = bundle.init_cache(B, S, abstract=True)
    return {"cache": cache, "token": tok((B,)), "pos": tok((B,))}
