"""Shared model components: param declaration, norms, rope, attention.

Params are declared via ``ParamDef`` trees so that a single declaration yields
(a) materialized weights, (b) logical sharding axes, and (c) eval_shape-only
abstract params for the dry-run.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones
    scale: float = 1.0                # stddev multiplier for normal


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(rng: jax.Array, defs: PyTree, dtype=jnp.float32) -> PyTree:
    """Materialize a ParamDef tree into weights (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))

    def make(key, d: ParamDef):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        fan_in = d.shape[0] if d.shape else 1
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, dtype) * std).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(k, d) for k, d in zip(keys, leaves)])


def abstract_params(defs: PyTree, dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct tree for dry-runs (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def)


def param_axes(defs: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda d: d.axes, defs, is_leaf=_is_def)


def stack_defs(defs: PyTree, n: int, axis_name: Optional[str] = None) -> PyTree:
    """Prepend a layer axis to every def (for lax.scan over layers)."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.scale),
        defs, is_leaf=_is_def)


def init_stacked(rng: jax.Array, defs: PyTree, n: int, dtype=jnp.float32) -> PyTree:
    """Materialize per-layer weights and stack along axis 0."""
    keys = jax.random.split(rng, n)
    return jax.vmap(lambda k: init_params(k, defs, dtype))(keys)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def act_fn(name: str) -> Callable[[jax.Array], jax.Array]:
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs         # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (pure-JAX; the Pallas kernels in repro.kernels are the TPU path)
# ---------------------------------------------------------------------------

def _scale(head_dim: int) -> float:
    return 1.0 / math.sqrt(head_dim)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  q_offset: Any = 0,
                  window: int = 0,
                  attn_softcap: float = 0.0,
                  kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Grouped-query attention, full-materialization path.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). q_offset: scalar or (B,) absolute
    position of q[0] (for decode). window>0 -> sliding-window (local) mask.
    kv_len: (B,) valid kv length mask (for decode caches).
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qh = q.reshape(B, Sq, Hkv, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) * _scale(D)
    logits = softcap(logits, attn_softcap)
    q_pos = (jnp.asarray(q_offset).reshape(-1, 1) + jnp.arange(Sq)[None])  # (B|1, Sq)
    k_pos = jnp.arange(Sk)[None]                                           # (1, Sk)
    mask = jnp.ones((q_pos.shape[0], Sq, Sk), dtype=bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[:, None, :]
    # window may be a traced per-layer scalar (scan over mixed local/global
    # layers); 0 means global.
    w = jnp.asarray(window)
    mask &= jnp.where(w > 0, q_pos[:, :, None] - k_pos[:, None, :] < w, True)
    if kv_len is not None:
        mask &= k_pos[:, None, :] < kv_len.reshape(-1, 1, 1)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: int = 0,
                      attn_softcap: float = 0.0,
                      chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention (memory O(Sq*chunk)).

    Used for long-sequence prefill/train where the (Sq, Sk) score matrix would
    not fit HBM. Scans over kv chunks carrying (acc, row_max, row_sum).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Sk % chunk:
        pad = chunk - Sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sk_p = Sk + pad
    else:
        Sk_p = Sk
    n_chunks = Sk_p // chunk
    g = Hq // Hkv
    qh = (q.astype(jnp.float32) * _scale(D)).reshape(B, Sq, Hkv, g, D)
    kc = k.reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D)
    q_pos = jnp.arange(Sq)

    def step(carry, xs):
        acc, m, s = carry
        kb, vb, ci = xs
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kb.astype(jnp.float32))
        logits = softcap(logits, attn_softcap)
        k_pos = ci * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < Sk
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, q_pos[:, None] - k_pos[None, :] < w, True)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        s_new = s * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, s_new), None

    acc0 = jnp.zeros((B, Hkv, g, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, g, Sq), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    (acc, m, s), _ = jax.lax.scan(
        step, (acc0, m0, s0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(s[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, attn_softcap=0.0,
              chunk_threshold: int = 8192) -> jax.Array:
    """Dispatch: full path for short seqs, chunked online-softmax for long."""
    if q.shape[1] >= chunk_threshold or k.shape[1] > chunk_threshold:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 attn_softcap=attn_softcap)
    return gqa_attention(q, k, v, causal=causal, window=window,
                         attn_softcap=attn_softcap)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None,
                 z_loss: float = 1e-4) -> jax.Array:
    """Mean cross-entropy over valid positions, with optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        return loss.mean()
    mask = mask.astype(jnp.float32)
    return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def scan_or_unroll(use_scan: bool, f, init, xs):
    """jax.lax.scan, or a python unroll when use_scan=False.

    The unrolled form exists for the dry-run *calibration* path: XLA's
    cost_analysis counts a while-loop body once regardless of trip count, so
    per-layer roofline costs are measured from small unrolled variants and
    extrapolated to full depth (see repro.launch.dryrun).
    """
    if use_scan:
        return jax.lax.scan(f, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    dim = np.arange(0, d, 2)[None]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)
