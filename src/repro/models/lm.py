"""Generic decoder-only LM stack: dense / MoE / VLM families.

Layers are stacked and executed with ``lax.scan`` (one compiled block body →
small HLO, fast multi-pod compiles). Heterogeneous layer patterns (gemma2
local/global alternation, hymba global-every-k) are expressed as a per-layer
``window`` array scanned alongside the stacked params, so the block body stays
homogeneous.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import moe as moe_lib
from repro.models.common import (ParamDef, act_fn, apply_rope, attention,
                                 gqa_attention, init_params, init_stacked,
                                 rms_norm, scan_or_unroll, softcap,
                                 softmax_xent)

PyTree = Any


# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        defs.update({
            "bq": ParamDef((cfg.n_heads, hd), ("heads", "head_dim"), "zeros"),
            "bk": ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), "zeros"),
            "bv": ParamDef((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), "zeros"),
        })
    return defs


def mlp_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef((d, ff), ("embed", "mlp")),
        "w_up": ParamDef((d, ff), ("embed", "mlp")),
        "w_down": ParamDef((ff, d), ("mlp", "embed")),
    }


def block_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {"ln1": ParamDef((cfg.d_model,), ("embed",), "zeros"),
                            "attn": attn_defs(cfg)}
    if not cfg.parallel_block:
        defs["ln2"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
    if cfg.post_norm:
        defs["pn1"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
        defs["pn2"] = ParamDef((cfg.d_model,), ("embed",), "zeros")
    if cfg.family == "moe":
        defs["moe"] = moe_lib.moe_defs(cfg)
    else:
        defs["mlp"] = mlp_defs(cfg)
    return defs


def lm_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    defs = {"embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed")),
            "final_norm": ParamDef((d,), ("embed",), "zeros")}
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))
    if cfg.n_image_tokens:
        # stub multimodal projector (patch-embed -> d_model), applied to the
        # precomputed patch embeddings supplied by input_specs()
        defs["mm_proj"] = ParamDef((d, d), ("embed", None))
    return defs


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer sliding window (0 = global attention)."""
    w = np.zeros((cfg.n_layers,), np.int32)
    if cfg.layer_pattern == "local_global" and cfg.local_window:
        w[0::2] = cfg.local_window           # even layers local (gemma2)
    elif cfg.global_every and cfg.local_window:
        w[:] = cfg.local_window              # hymba: local everywhere ...
        w[0::cfg.global_every] = 0           # ... except every k-th global
    return w


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """Repeat kv heads to the q-head count.

    The XLA path always computes attention in MHA form: identical FLOPs to the
    grouped form, but sharding then follows a single q-heads rule (kv stays
    grouped only inside the KV *cache*, where the memory matters). The Pallas
    kernels keep the grouped form.
    """
    g = n_heads // k.shape[2]
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def _pallas_ok(run: Optional[RunConfig], q, window) -> bool:
    """Use the flash kernel when enabled and the shapes fit its blocks.

    On non-TPU backends the kernel runs in interpret mode — only sensible
    for tiny test shapes, so restrict to TPU unless the problem is small.
    """
    if run is None or not run.use_pallas or isinstance(window, jax.Array):
        return False
    B, S = q.shape[0], q.shape[1]
    if S % 128 and S % 64:
        return False
    if jax.default_backend() == "tpu":
        return True
    return B * S <= 4096          # interpret-mode (tests/examples) only


def attention_with_knobs(q, ke, ve, *, n_heads: int, causal=True, window=0,
                         attn_softcap=0.0, run: Optional[RunConfig] = None,
                         mesh=None, batch_axes=("data",),
                         pre_resharded: bool = False):
    """Full-seq attention with the §Perf sharding knobs.

    ke/ve are already expanded to q-heads. Two mutually-useful strategies for
    archs whose heads don't divide TP:
      * attn_pad_heads: pad heads to a TP multiple -> shard over `model`,
        zero reshard collectives, pad/Hq wasted flops;
      * attn_batch_reshard (`pre_resharded`): caller spread the batch over
        (batch_axes + model); attention is pure-DP; reshard back after.

    With ``run.use_pallas`` the flash-attention Pallas kernel replaces the
    XLA einsum path (TPU; interpret mode for small test shapes elsewhere).
    """
    pad_heads = (run is not None and run.attn_pad_heads and mesh is not None
                 and "model" in getattr(mesh, "axis_names", ()))
    if pad_heads:
        from jax.sharding import NamedSharding, PartitionSpec as P
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        target = -(-n_heads // tp) * tp
        if target != n_heads:
            padw = ((0, 0), (0, 0), (0, target - n_heads), (0, 0))
            q, ke, ve = (jnp.pad(t, padw) for t in (q, ke, ve))
        spec = NamedSharding(
            mesh, P(tuple(batch_axes) or None, None, "model", None))
        q, ke, ve = (jax.lax.with_sharding_constraint(t, spec)
                     for t in (q, ke, ve))
    if _pallas_ok(run, q, window):
        from repro.kernels.flash_attention.kernel import flash_attention
        block = 128 if q.shape[1] % 128 == 0 else 64
        out = flash_attention(
            q.transpose(0, 2, 1, 3), ke.transpose(0, 2, 1, 3),
            ve.transpose(0, 2, 1, 3), causal=causal, window=int(window),
            softcap=attn_softcap, block_q=block, block_k=block,
            interpret=jax.default_backend() != "tpu",
        ).transpose(0, 2, 1, 3)
    else:
        out = attention(q, ke, ve, causal=causal, window=window,
                        attn_softcap=attn_softcap)
    if pad_heads:
        out = out[:, :, :n_heads]
    if pre_resharded:
        from jax.sharding import NamedSharding, PartitionSpec as P
        back = NamedSharding(mesh, P(tuple(batch_axes), None, None, None))
        out = jax.lax.with_sharding_constraint(out, back)
    return out


def _attn_apply(p, cfg: ModelConfig, x, *, window, cache=None, pos=None,
                run: Optional[RunConfig] = None, mesh=None,
                batch_axes=("data",)):
    """x: (B, S, d). cache: dict(k,v) (B, Smax, Hkv, hd) or None.

    Returns (out (B,S,d), new_cache).
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)

    # §Perf knob: when heads don't divide TP (attention would replicate over
    # `model`), spread the *batch* over (batch_axes + model) just for the
    # attention op — pure DP attention, two reshards per layer.
    reshard = (run is not None and run.attn_batch_reshard and mesh is not None
               and cache is None)
    if reshard:
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(batch_axes) + ("model",)
        spread = NamedSharding(mesh, P(axes, None, None, None))
        q, k, v = (jax.lax.with_sharding_constraint(t, spread)
                   for t in (q, k, v))

    if cache is None:
        # train/prefill-from-scratch: positions 0..S
        positions = jnp.arange(S)[None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = attention_with_knobs(
            q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
            n_heads=cfg.n_heads, causal=True, window=window,
            attn_softcap=cfg.attn_softcap, run=run, mesh=mesh,
            batch_axes=batch_axes, pre_resharded=reshard)
        new_cache = None
    elif S > 1:
        # prefill: full-seq attention, write K/V into cache positions [0, S)
        positions = jnp.arange(S)[None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        out = attention(q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
                        causal=True, window=window,
                        attn_softcap=cfg.attn_softcap)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        new_cache = {"k": ck, "v": cv}
    else:
        # decode: S == 1, write at per-sequence position `pos`.
        # The write is a broadcast-compare-select rather than a scatter: an
        # elementwise update keeps every dim of the cache shardable under
        # SPMD (a dynamic scatter into a sequence-sharded cache would force
        # an all-gather).
        positions = pos[:, None] + jnp.arange(S)[None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        write = (jnp.arange(cache["k"].shape[1])[None, :, None, None]
                 == pos[:, None, None, None])
        ck = jnp.where(write, k[:, :1].astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(write, v[:, :1].astype(cache["v"].dtype), cache["v"])
        if run is not None and run.decode_cache_anchor and mesh is not None:
            # §Perf knob: anchor the updated cache to its input sharding so
            # SPMD reshards the (tiny) broadcast operand instead of
            # all-gathering the whole sequence-sharded cache.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs.base import ShapeConfig
            from repro.distributed import sharding as shlib
            sh = shlib.cache_shardings(
                cfg, mesh, ShapeConfig("t", "decode", cache["k"].shape[1], B))
            inner = NamedSharding(mesh, P(*sh["k"].spec[1:]))
            ck = jax.lax.with_sharding_constraint(ck, inner)
            cv = jax.lax.with_sharding_constraint(cv, inner)
        # §Perf knob: for S==1 the kv_len mask (k_pos < pos+1) is exactly the
        # causal mask — skip the redundant (B, S_cache) causal compare
        causal = not (run is not None and run.decode_slim_mask and S == 1)
        if run is not None and run.decode_grouped:
            # §Perf knob: grouped-query form reads the KV cache once instead
            # of q_per_kv times (no materialized expansion)
            out = gqa_attention(q, ck.astype(x.dtype), cv.astype(x.dtype),
                                causal=causal, q_offset=pos, window=window,
                                attn_softcap=cfg.attn_softcap,
                                kv_len=pos + S)
        else:
            out = gqa_attention(q, _expand_kv(ck.astype(x.dtype), cfg.n_heads),
                                _expand_kv(cv.astype(x.dtype), cfg.n_heads),
                                causal=causal, q_offset=pos, window=window,
                                attn_softcap=cfg.attn_softcap,
                                kv_len=pos + S)
        new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def _mlp_apply(p, cfg: ModelConfig, x):
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))) * \
        jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


def apply_block(p, cfg: ModelConfig, run: RunConfig, x, *, window,
                mesh=None, batch_axes=("data",), cache=None, pos=None):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.parallel_block:
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, new_cache = _attn_apply(p["attn"], cfg, h, window=window,
                                   cache=cache, pos=pos, run=run, mesh=mesh,
                                   batch_axes=batch_axes)
        if cfg.family == "moe":
            m, aux = moe_lib.moe_apply(h, p["moe"], cfg, run, mesh, batch_axes)
        else:
            m = _mlp_apply(p["mlp"], cfg, h)
        x = x + a + m
        return x, new_cache, aux
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = _attn_apply(p["attn"], cfg, h, window=window,
                               cache=cache, pos=pos, run=run, mesh=mesh,
                               batch_axes=batch_axes)
    if cfg.post_norm:
        a = rms_norm(a, p["pn1"], cfg.norm_eps)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_lib.moe_apply(h, p["moe"], cfg, run, mesh, batch_axes)
    else:
        m = _mlp_apply(p["mlp"], cfg, h)
    if cfg.post_norm:
        m = rms_norm(m, p["pn2"], cfg.norm_eps)
    x = x + m
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def full_defs(cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.common import stack_defs
    return {"lm": lm_defs(cfg),
            "blocks": stack_defs(block_defs(cfg), cfg.n_layers, "layers")}


def init(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    r1, r2 = jax.random.split(rng)
    return {"lm": init_params(r1, lm_defs(cfg), dtype),
            "blocks": init_stacked(r2, block_defs(cfg), cfg.n_layers, dtype)}


def _embed(params, cfg: ModelConfig, run: RunConfig, batch):
    emb = params["lm"]["embed"]
    x = emb[batch["tokens"]].astype(run.compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.n_image_tokens and "image_embeds" in batch:
        img = jnp.einsum("bsd,de->bse", batch["image_embeds"].astype(x.dtype),
                         params["lm"]["mm_proj"].astype(x.dtype))
        x = jnp.concatenate([img, x], axis=1)
    return x


def _unembed(params, cfg: ModelConfig, x):
    emb = params["lm"]["embed"]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, emb.astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm"]["lm_head"].astype(x.dtype))
    return softcap(logits, cfg.logit_softcap)


def forward_train(params, cfg: ModelConfig, run: RunConfig, batch,
                  mesh=None, batch_axes=("data",)):
    """Full-sequence forward. batch: tokens (B,S[,image_embeds…]).

    Returns (logits (B,S,V), aux_loss).
    """
    x = _embed(params, cfg, run, batch)
    win_np = layer_windows(cfg)
    homogeneous = bool((win_np == 0).all())   # static window enables kernels
    windows = jnp.asarray(win_np)

    def body(x, xs):
        p_l, w_l = xs
        x, _, aux = apply_block(p_l, cfg, run, x,
                                window=0 if homogeneous else w_l, mesh=mesh,
                                batch_axes=batch_axes)
        return x, aux

    if run.scan_layers:
        block_fn = body
        if run.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if run.remat == "dots" else
                      jax.checkpoint_policies.nothing_saveable)
            block_fn = jax.checkpoint(body, policy=policy)
        x, auxs = jax.lax.scan(block_fn, x, (params["blocks"], windows))
        aux = auxs.sum()
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.n_layers):
            p_l = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, a = body(x, (p_l, windows[i]))
            aux = aux + a
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x), aux


def train_loss(params, cfg: ModelConfig, run: RunConfig, batch,
               mesh=None, batch_axes=("data",)):
    logits, aux = forward_train(params, cfg, run, batch, mesh, batch_axes)
    labels = batch["labels"]
    if cfg.n_image_tokens and "image_embeds" in batch:
        logits = logits[:, cfg.n_image_tokens:]
    mask = batch.get("loss_mask")
    return softmax_xent(logits, labels, mask) + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> PyTree:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16) -> PyTree:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def decode_step(params, cfg: ModelConfig, run: RunConfig, cache, token, pos,
                mesh=None, batch_axes=("data",)):
    """One decode step. token: (B,) int32; pos: (B,) int32 current lengths.

    Returns (logits (B,V), new_cache).
    """
    batch = {"tokens": token[:, None]}
    x = _embed(params, cfg, run, batch)
    windows = jnp.asarray(layer_windows(cfg))

    def body(x, xs):
        p_l, w_l, cache_l = xs
        x, new_cache_l, _ = apply_block(p_l, cfg, run, x, window=w_l,
                                        mesh=mesh, batch_axes=batch_axes,
                                        cache=cache_l, pos=pos)
        return x, new_cache_l

    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["blocks"], windows, cache))
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    return logits[:, 0], new_cache


def prefill(params, cfg: ModelConfig, run: RunConfig, cache, tokens,
            mesh=None, batch_axes=("data",), extra=None):
    """Fill cache positions [0, S) and return last-position logits.

    tokens: (B, S). Returns (logits (B,V), cache, lengths (B,)).
    """
    B, S = tokens.shape
    batch = {"tokens": tokens}
    if extra:
        batch.update(extra)
    x = _embed(params, cfg, run, batch)
    win_np = layer_windows(cfg)
    homogeneous = bool((win_np == 0).all())
    windows = jnp.asarray(win_np)

    def body(x, xs):
        p_l, w_l, cache_l = xs
        x, new_cache_l, _ = _prefill_block(p_l, cfg, run, x,
                                           0 if homogeneous else w_l,
                                           cache_l, mesh, batch_axes)
        return x, new_cache_l

    # cache length = embedded length (vlm: image tokens prepended to text)
    emb_len = x.shape[1]
    x, new_cache = scan_or_unroll(run.scan_layers, body, x,
                                  (params["blocks"], windows, cache))
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits[:, 0], new_cache, jnp.full((B,), emb_len, jnp.int32)


def _prefill_block(p, cfg, run, x, window, cache_l, mesh, batch_axes):
    """Block application that also writes the full-seq K/V into the cache."""
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"].astype(h.dtype))
    if cfg.qkv_bias:
        q = q + p["attn"]["bq"].astype(h.dtype)
        k = k + p["attn"]["bk"].astype(h.dtype)
        v = v + p["attn"]["bv"].astype(h.dtype)
    positions = jnp.arange(S)[None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attention(q, _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads),
                    causal=True, window=window, attn_softcap=cfg.attn_softcap)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache_l["k"], k.astype(cache_l["k"].dtype), 0, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache_l["v"], v.astype(cache_l["v"].dtype), 0, axis=1)
    a = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(h.dtype))
    if cfg.post_norm:
        a = rms_norm(a, p["pn1"], cfg.norm_eps)
    if cfg.parallel_block:
        if cfg.family == "moe":
            m, _ = moe_lib.moe_apply(h, p["moe"], cfg, run, mesh, batch_axes)
        else:
            m = _mlp_apply(p["mlp"], cfg, h)
        return x + a + m, {"k": ck, "v": cv}, None
    x = x + a
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m, _ = moe_lib.moe_apply(h2, p["moe"], cfg, run, mesh, batch_axes)
    else:
        m = _mlp_apply(p["mlp"], cfg, h2)
    if cfg.post_norm:
        m = rms_norm(m, p["pn2"], cfg.norm_eps)
    return x + m, {"k": ck, "v": cv}, None
