"""Version-compat shims for the pinned jax (0.4.37).

Every deprecated/moved jax API the repo touches is funneled through this
module, so a future jax bump is a one-file change:

  * ``shard_map`` — lives at ``jax.experimental.shard_map.shard_map`` in the
    pinned release (kwarg ``check_rep``) and at ``jax.shard_map`` (kwarg
    ``check_vma``) after jax 0.6. The shim resolves whichever exists and
    translates the check kwarg, so call sites can uniformly pass the modern
    ``check_vma`` name.
  * ``tree_map`` — ``jax.tree_map`` was removed; ``jax.tree_util.tree_map``
    works on every release we care about (``jax.tree.map`` only post-0.4.25).
  * jaxpr introspection types (``Jaxpr``/``ClosedJaxpr``) — moved from
    ``jax.core`` to ``jax.extend.core``; plus the nested-jaxpr walkers the
    perf-invariant tests share.
"""
from __future__ import annotations

import inspect
from typing import Iterator, Tuple

import jax

# ---------------------------------------------------------------------------
# tree_map: one non-deprecated spelling for every supported release
# ---------------------------------------------------------------------------

tree_map = jax.tree_util.tree_map

# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def _resolve_shard_map():
    """(impl, name_of_replication_check_kwarg) for this jax version."""
    try:                                     # pinned 0.4.x location
        from jax.experimental.shard_map import shard_map as impl
    except ImportError:                      # jax >= 0.6: top-level
        impl = jax.shard_map
    params = inspect.signature(impl).parameters
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return impl, kw
    return impl, None


_SHARD_MAP_IMPL, _CHECK_KW = _resolve_shard_map()


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """`jax.shard_map` signature (modern `check_vma` kwarg), any jax version."""
    if _CHECK_KW is not None and _CHECK_KW not in kw:
        kw[_CHECK_KW] = check_vma
    return _SHARD_MAP_IMPL(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# jit cache introspection (private API, name has moved across releases)
# ---------------------------------------------------------------------------


def jit_cache_size(fn) -> int:
    """Number of distinct compiled programs behind a jitted function."""
    for attr in ("_cache_size", "cache_size"):
        size = getattr(fn, attr, None)
        if size is not None:
            return size() if callable(size) else size
    raise AttributeError(
        f"no jit cache-size accessor on {fn!r} for jax {jax.__version__}; "
        f"update repro.compat.jit_cache_size")


# ---------------------------------------------------------------------------
# jaxpr introspection (moved out of jax.core)
# ---------------------------------------------------------------------------

try:                                         # jax >= 0.4.33 new-style location
    from jax.extend.core import ClosedJaxpr, Jaxpr  # noqa: F401
except ImportError:                          # older releases
    from jax.core import ClosedJaxpr, Jaxpr  # noqa: F401


def sub_jaxprs(value) -> list:
    """All jaxprs hiding inside an eqn param value (list/tuple/closed)."""
    if isinstance(value, ClosedJaxpr):
        return [value.jaxpr]
    if isinstance(value, Jaxpr):
        return [value]
    if isinstance(value, (list, tuple)):
        return [j for v in value for j in sub_jaxprs(v)]
    return []


def walk_primitives(jaxpr, in_cond: bool = False
                    ) -> Iterator[Tuple[str, bool]]:
    """Yield (primitive_name, inside_cond_branch) over all nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn.primitive.name, in_cond
        child_in_cond = in_cond or eqn.primitive.name == "cond"
        for v in eqn.params.values():
            for sub in sub_jaxprs(v):
                yield from walk_primitives(sub, child_in_cond)
