"""PAR-BS (Mutlu & Moscibroda, ISCA'08): batch the oldest `parbs_cap`
requests per (source, bank), serve marked batches with shortest-job-first
source ranking before anything unmarked.

The seed implementation re-ran an O(C·E log E) CAM sort plus an SJF argsort
every cycle. Both are gone from the hot loop:

  * `grank` — each entry's age rank within its (source, bank) group — is
    maintained incrementally. Births are strictly increasing per source
    (one pending register), so admission order IS birth order within a
    group: a new entry's rank is just the group's current population, and
    an issue decrements the rank of its younger group-mates. Remarking
    becomes the elementwise test `valid & (grank < parbs_cap)`.
  * remarking itself runs in `pre_tick` as a plain elementwise select — no
    cond needed once the sort is gone;
  * the SJF ranking of `marked_left` is recomputed in `boundary_tick`
    behind a cond over (S,)-shaped state only, firing when the counts
    changed: after a marked issue (tracked by `pend_dec`, consumed here so
    `marked_left` keeps the exact recompute-at-tick timing) or when a
    batch is exhausted and a new one forms (`remarked`).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.schedulers import (CentralizedPolicy, POL_BIT, RANK_SHIFT,
                                   rank_pos)


@policy.register
class PARBS(CentralizedPolicy):
    name = "parbs"
    boundary_keys = ("marked_left", "pend_dec", "pri_src")
    # stacked schema: (C, E) grank + (S,) batch counters + scalar remarked.
    # Beyond the boundary keys, on_admit seeds grank, pre_tick re-marks
    # (marked/remarked), and on_issue shifts grank / defers the decrement.
    stacked_tick_keys = boundary_keys + ("grank", "marked", "remarked")
    stacked_issue_keys = ("grank", "pend_dec")

    def extra_state(self, cfg):
        C, E, S = cfg.n_channels, cfg.buf_entries, cfg.n_src
        return {
            "marked_left": jnp.zeros((S,), jnp.int32),
            "grank": jnp.zeros((C, E), jnp.int32),
            "pend_dec": jnp.zeros((S,), jnp.int32),
            "pri_src": jnp.zeros((S,), jnp.int32),
            "remarked": jnp.zeros((), bool),
        }

    def on_admit(self, cfg, pool, st, buf, do, slot, src, t):
        # the admitted entry is its group's youngest: rank = group size - 1
        buf = dict(buf)
        cidx = jnp.arange(cfg.n_channels)
        safe = jnp.where(do, slot, 0)
        bank = buf["bank"][cidx, safe]
        grp = buf["valid"] & (buf["src"] == src[:, None]) & \
            (buf["bank"] == bank[:, None])
        rank = jnp.sum(grp, axis=1).astype(jnp.int32) - 1
        buf["grank"] = engine.masked_set(buf["grank"], slot, rank, do)
        return buf

    def pre_tick(self, cfg, pool, st, buf, t):
        # re-mark when no marked requests remain: with grank maintained
        # incrementally this is a plain elementwise select, run every cycle
        buf = dict(buf)
        any_marked = jnp.any(buf["valid"] & buf["marked"])
        buf["marked"] = jnp.where(any_marked, buf["marked"],
                                  buf["valid"] & (buf["grank"]
                                                  < cfg.parbs_cap))
        buf["remarked"] = ~any_marked
        return buf

    def boundary_pred(self, cfg, pool, st, buf, t):
        # fire on any marked-count change: a marked issue last cycle, or a
        # fresh re-mark. Data-dependent, so under vmap this degrades to
        # select — but the branch touches only (S,) state and the sort
        # stays out of the per-cycle jaxpr.
        return buf["remarked"] | jnp.any(buf["pend_dec"] != 0)

    def boundary_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        # re-mark: recount from scratch (ground truth for the new batch);
        # otherwise apply the deferred per-issue decrements. One-hot
        # compare-and-reduce, not a scatter: XLA:CPU executes the dense
        # reduction an order of magnitude faster inside the scan.
        onehot = (buf["src"][..., None] == jnp.arange(S)) & \
            (buf["marked"] & buf["valid"])[..., None]       # (C, E, S)
        cnt = jnp.sum(onehot, axis=(0, 1)).astype(jnp.int32)
        buf["marked_left"] = jnp.where(buf["remarked"], cnt,
                                       buf["marked_left"] - buf["pend_dec"])
        buf["pend_dec"] = jnp.zeros_like(buf["pend_dec"])
        # shortest-job ranking: fewest marked = best
        rank = rank_pos(buf["marked_left"])
        buf["pri_src"] = (S - rank).astype(jnp.int32) << RANK_SHIFT
        return buf

    def on_issue(self, cfg, pool, buf, do, pick, src, t):
        buf = dict(buf)
        cidx = jnp.arange(cfg.n_channels)
        safe = jnp.where(do, pick, 0)
        bank = buf["bank"][cidx, safe]
        birth = buf["birth"][cidx, safe]
        was_marked = buf["marked"][cidx, safe]
        # younger group-mates move up one rank
        younger = buf["valid"] & (buf["src"] == src[:, None]) & \
            (buf["bank"] == bank[:, None]) & \
            (buf["birth"] > birth[:, None]) & do[:, None]
        buf["grank"] = buf["grank"] - younger.astype(jnp.int32)
        # defer the marked_left decrement to the next boundary_tick so the
        # count keeps the seed's recompute-at-tick timing exactly
        buf["pend_dec"] = engine.accum_by_index(
            buf["pend_dec"], src, 1, do & was_marked)
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        return buf["marked"].astype(jnp.int32) * POL_BIT + \
            super().score(cfg, pool, buf, is_hit, t)
