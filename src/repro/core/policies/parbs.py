"""PAR-BS (Mutlu & Moscibroda, ISCA'08): batch the oldest `parbs_cap`
requests per (source, bank), serve marked batches with shortest-job-first
source ranking before anything unmarked.

The seed implementation re-ran an O(C·E log E) CAM sort plus an SJF argsort
every cycle; PR 2 moved them behind a data-dependent boundary cond. That
cond was the last batched-predicate residue on the stacked path — under
`vmap` it degrades to `select`, inlining both branches every cycle. This
version needs neither cond nor sort (the amortized-rank form):

  * `grank` — each entry's age rank within its (source, bank) group — is
    maintained incrementally. Births are strictly increasing per source
    (one pending register), so admission order IS birth order within a
    group: a new entry's rank is just the group's current population, and
    an issue decrements the rank of its younger group-mates. Remarking
    becomes the elementwise test `valid & (grank < parbs_cap)`.
  * `msub` — the would-be-marked population per source, i.e. the size of
    `valid & (grank < cap)` — is maintained incrementally too (admit adds
    below-cap entries; an issue removes the entry and promotes at most one
    below-cap group-mate per channel), so batch re-formation assigns
    `marked_left` from a counter instead of a (C, E, S) recount.
  * the SJF priority is a pairwise stable rank of the (S,) `marked_left`
    vector — O(S^2) elementwise compares, no sort primitive — cheap enough
    to recompute unconditionally in `pre_tick`. Between batch events
    `marked_left` is constant, so the recompute is a fixed point and the
    cached `pri_src` stays bit-identical to the cond-gated original.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.schedulers import CentralizedPolicy, POL_BIT, RANK_SHIFT


def pairwise_rank(key: jnp.ndarray) -> jnp.ndarray:
    """Stable ascending rank (0 = smallest, ties broken by index) as an
    O(S^2) compare-and-sum — matches `rank_pos` (argsort∘argsort) exactly
    without a sort primitive, so it may run in the per-cycle jaxpr."""
    lt = key[None, :] < key[:, None]
    idx = jnp.arange(key.shape[0])
    tie = (key[None, :] == key[:, None]) & (idx[None, :] < idx[:, None])
    return jnp.sum(lt | tie, axis=1).astype(jnp.int32)


@policy.register
class PARBS(CentralizedPolicy):
    name = "parbs"
    boundary_keys = ()
    # stacked schema: (C, E) grank + (S,) batch counters. on_admit seeds
    # grank/msub, pre_tick re-marks and ranks, on_issue shifts grank /
    # settles msub / defers the marked_left decrement.
    stacked_tick_keys = ("marked_left", "pend_dec", "pri_src", "grank",
                         "marked", "msub")
    stacked_issue_keys = ("grank", "pend_dec", "msub")

    def extra_state(self, cfg):
        C, E, S = cfg.n_channels, cfg.buf_entries, cfg.n_src
        return {
            "marked_left": jnp.zeros((S,), jnp.int32),
            "grank": jnp.zeros((C, E), jnp.int32),
            "pend_dec": jnp.zeros((S,), jnp.int32),
            "pri_src": jnp.zeros((S,), jnp.int32),
            "msub": jnp.zeros((S,), jnp.int32),
        }

    def on_admit(self, cfg, pool, st, buf, do, slot, src, t):
        # the admitted entry is its group's youngest: rank = group size - 1
        buf = dict(buf)
        cidx = jnp.arange(cfg.n_channels)
        safe = jnp.where(do, slot, 0)
        bank = buf["bank"][cidx, safe]
        grp = buf["valid"] & (buf["src"] == src[:, None]) & \
            (buf["bank"] == bank[:, None])
        rank = jnp.sum(grp, axis=1).astype(jnp.int32) - 1
        buf["grank"] = engine.masked_set(buf["grank"], slot, rank, do)
        buf["msub"] = engine.accum_by_index(
            buf["msub"], src, 1, do & (rank < cfg.parbs_cap))
        return buf

    def pre_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        # apply the decrements deferred by on_issue (keeps the seed's
        # recompute-at-tick timing exactly), then re-mark when no marked
        # requests remain — `msub` is the recount, already maintained
        buf["marked_left"] = buf["marked_left"] - buf["pend_dec"]
        buf["pend_dec"] = jnp.zeros_like(buf["pend_dec"])
        any_marked = jnp.any(buf["valid"] & buf["marked"])
        buf["marked"] = jnp.where(any_marked, buf["marked"],
                                  buf["valid"] & (buf["grank"]
                                                  < cfg.parbs_cap))
        buf["marked_left"] = jnp.where(any_marked, buf["marked_left"],
                                       buf["msub"])
        # shortest-job ranking: fewest marked = best. Sort-free and a fixed
        # point between batch events, so it runs unconditionally.
        rank = pairwise_rank(buf["marked_left"])
        buf["pri_src"] = (S - rank).astype(jnp.int32) << RANK_SHIFT
        return buf

    def on_issue(self, cfg, pool, buf, do, pick, src, t):
        buf = dict(buf)
        cidx = jnp.arange(cfg.n_channels)
        safe = jnp.where(do, pick, 0)
        bank = buf["bank"][cidx, safe]
        birth = buf["birth"][cidx, safe]
        was_marked = buf["marked"][cidx, safe]
        was_below = buf["grank"][cidx, safe] < cfg.parbs_cap
        # younger group-mates move up one rank; any mate sitting exactly at
        # the cap (at most one per channel — ranks are distinct in a group)
        # enters the would-be-marked set, the issued entry leaves it
        younger = buf["valid"] & (buf["src"] == src[:, None]) & \
            (buf["bank"] == bank[:, None]) & \
            (buf["birth"] > birth[:, None]) & do[:, None]
        at_cap = jnp.sum(younger & (buf["grank"] == cfg.parbs_cap),
                         axis=1).astype(jnp.int32)
        buf["grank"] = buf["grank"] - younger.astype(jnp.int32)
        buf["msub"] = engine.accum_by_index(
            buf["msub"], src, at_cap - was_below.astype(jnp.int32), do)
        # defer the marked_left decrement to the next pre_tick so the
        # count keeps the seed's recompute-at-tick timing exactly
        buf["pend_dec"] = engine.accum_by_index(
            buf["pend_dec"], src, 1, do & was_marked)
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        return buf["marked"].astype(jnp.int32) * POL_BIT + \
            super().score(cfg, pool, buf, is_hit, t)

    def check_invariants(self, cfg, pool, st, buf, t):
        # base buffer invariants + the two PAR-BS mirror counters: `grank`
        # must equal a pairwise age-rank recount within each (source, bank)
        # group (births are distinct within a group, so strict-< is exact),
        # and `msub` must equal a recount of the would-be-marked set. This
        # is the check the corrupted-write-set fault trips on the stacked
        # path: dropping `msub` from the declared keys desyncs the counter.
        bad = super().check_invariants(cfg, pool, st, buf, t)
        v = buf["valid"]
        same = v[:, :, None] & v[:, None, :] & \
            (buf["src"][:, :, None] == buf["src"][:, None, :]) & \
            (buf["bank"][:, :, None] == buf["bank"][:, None, :])
        older = same & (buf["birth"][:, None, :] < buf["birth"][:, :, None])
        rank = jnp.sum(older, axis=2).astype(jnp.int32)
        bad += jnp.sum((v & (rank != buf["grank"])).astype(jnp.int32))
        below = v & (buf["grank"] < cfg.parbs_cap)
        cnt = jnp.sum(((jnp.arange(cfg.n_src)[None, None, :]
                        == buf["src"][:, :, None]) &
                       below[:, :, None]).astype(jnp.int32), axis=(0, 1))
        bad += jnp.sum((cnt != buf["msub"]).astype(jnp.int32))
        return bad

    def next_boundary(self, cfg, pool, st, buf, t):
        # pre_tick mutates state next cycle iff deferred decrements are
        # pending or a fresh batch would form; otherwise every term it
        # writes is a fixed point and the span may skip it
        pend = jnp.any(buf["pend_dec"] != 0)
        reform = ~jnp.any(buf["valid"] & buf["marked"]) & \
            jnp.any(buf["valid"])
        return jnp.where(pend | reform, t + 1, jnp.int32(engine.INF_T))
