"""PAR-BS (Mutlu & Moscibroda, ISCA'08): batch the oldest `parbs_cap`
requests per (source, bank), serve marked batches with shortest-job-first
source ranking before anything unmarked."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import policy
from repro.core.schedulers import (CentralizedPolicy, POL_BIT, RANK_SHIFT,
                                   base_score, rank_pos)


@policy.register
class PARBS(CentralizedPolicy):
    name = "parbs"

    def extra_state(self, cfg):
        return {"marked_left": jnp.zeros((cfg.n_src,), jnp.int32)}

    def policy_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        # re-mark when no marked requests remain anywhere
        any_marked = jnp.any(buf["valid"] & buf["marked"])

        # per (channel, src, bank) age rank via one sort (O(E log E)):
        # sort by (group, birth); rank-in-group = index - group_start
        def remark_channel(valid, src, bank, birth):
            E = valid.shape[0]
            # int32-safe packing: group (<= 9 bits) above birth (21 bits)
            group = jnp.where(valid, src * cfg.n_banks + bank, (1 << 9) - 1)
            key = group * (1 << 21) + jnp.clip(birth, 0, (1 << 21) - 1)
            order = jnp.argsort(key)
            g_sorted = group[order]
            new_seg = jnp.concatenate([jnp.array([True]),
                                       g_sorted[1:] != g_sorted[:-1]])
            seg_start = jax.lax.cummax(
                jnp.where(new_seg, jnp.arange(E), 0))
            rank_sorted = jnp.arange(E) - seg_start
            rank = jnp.zeros((E,), jnp.int32).at[order].set(
                rank_sorted.astype(jnp.int32))
            return valid & (rank < cfg.parbs_cap)

        new_marked = jax.vmap(remark_channel)(
            buf["valid"], buf["src"], buf["bank"], buf["birth"])
        buf["marked"] = jnp.where(any_marked, buf["marked"], new_marked)
        # shortest-job ranking: total marked per src (fewest = best)
        cnt = jnp.zeros((S,), jnp.int32).at[
            jnp.where(buf["marked"] & buf["valid"], buf["src"], S)
        ].add(1, mode="drop")
        buf["marked_left"] = cnt
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        S = cfg.n_src
        rank = rank_pos(buf["marked_left"])             # fewest marked = 0
        pri = (S - rank[buf["src"]]).astype(jnp.int32) << RANK_SHIFT
        return buf["marked"].astype(jnp.int32) * POL_BIT + pri + \
            base_score(cfg, buf, is_hit, t)
