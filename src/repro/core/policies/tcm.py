"""TCM (Kim et al., MICRO'10): cluster sources into a latency-sensitive
group (prioritized, ranked by ascending intensity) and a bandwidth group
(rank-shuffled every quantum to spread interference)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import policy
from repro.core.schedulers import (CentralizedPolicy, POL_BIT, RANK_SHIFT,
                                   base_score, rank_pos)


@policy.register
class TCM(CentralizedPolicy):
    name = "tcm"

    def extra_state(self, cfg):
        S = cfg.n_src
        return {
            "served_quant": jnp.zeros((S,), jnp.float32),
            "tcm_rank": jnp.zeros((S,), jnp.int32),
            "tcm_is_lat": jnp.ones((S,), bool),
            "shuffle": jnp.zeros((), jnp.int32),
        }

    def policy_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        quant = jnp.mod(t, cfg.tcm_quantum) == 0
        inten = buf["served_quant"]                     # MPKC proxy
        order = rank_pos(inten)                         # ascending intensity
        total = jnp.maximum(jnp.sum(inten), 1.0)
        # latency cluster: least-intense prefix holding <= lat_frac of BW
        sorted_i = jnp.sort(inten)
        cum = jnp.cumsum(sorted_i)
        is_lat_sorted = cum <= cfg.tcm_lat_frac * total
        new_is_lat = is_lat_sorted[order]
        # ranks: latency cluster by ascending intensity; bw cluster shuffled
        shuf = buf["shuffle"] + quant.astype(jnp.int32)
        lat_rank = order
        bw_rank = jnp.mod(order + shuf, S)
        new_rank = jnp.where(new_is_lat, lat_rank, bw_rank)
        buf["tcm_is_lat"] = jnp.where(quant, new_is_lat, buf["tcm_is_lat"])
        buf["tcm_rank"] = jnp.where(quant, new_rank, buf["tcm_rank"])
        buf["served_quant"] = jnp.where(quant, 0.0, buf["served_quant"])
        buf["shuffle"] = shuf
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        S = cfg.n_src
        src = buf["src"]
        pri = (S - buf["tcm_rank"][src]).astype(jnp.int32) << RANK_SHIFT
        return buf["tcm_is_lat"][src].astype(jnp.int32) * POL_BIT + pri + \
            base_score(cfg, buf, is_hit, t)

    def on_issue(self, cfg, pool, buf, do, src, t):
        buf = dict(buf)
        safe = jnp.where(do, src, 0)
        buf["served_quant"] = buf["served_quant"].at[safe].add(
            do.astype(jnp.float32))
        return buf
