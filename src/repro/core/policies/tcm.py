"""TCM (Kim et al., MICRO'10): cluster sources into a latency-sensitive
group (prioritized, ranked by ascending intensity) and a bandwidth group
(rank-shuffled every quantum to spread interference).

Clustering, ranking, and the shuffle only change at quantum boundaries, so
all of it lives in `boundary_tick` behind a `lax.cond` on the scalar cycle
counter; `score` gathers the cached per-source priority.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.schedulers import (CentralizedPolicy, POL_BIT, RANK_SHIFT,
                                   rank_pos)


@policy.register
class TCM(CentralizedPolicy):
    name = "tcm"
    boundary_keys = ("served_quant", "tcm_rank", "tcm_is_lat", "shuffle",
                     "pri_src")
    # stacked schema: (S,) cluster/rank state + scalar shuffle; tick writes
    # are boundary-only (the default), on_issue maintains the quantum counter
    stacked_issue_keys = ("served_quant",)

    def extra_state(self, cfg):
        S = cfg.n_src
        return {
            "served_quant": jnp.zeros((S,), jnp.float32),
            "tcm_rank": jnp.zeros((S,), jnp.int32),
            "tcm_is_lat": jnp.ones((S,), bool),
            "shuffle": jnp.zeros((), jnp.int32),
            "pri_src": jnp.zeros((S,), jnp.int32),
        }

    def boundary_pred(self, cfg, pool, st, buf, t):
        return jnp.mod(t, cfg.tcm_quantum) == 0

    def boundary_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        inten = buf["served_quant"]                     # MPKC proxy
        order = rank_pos(inten)                         # ascending intensity
        total = jnp.maximum(jnp.sum(inten), 1.0)
        # latency cluster: least-intense prefix holding <= lat_frac of BW
        sorted_i = jnp.sort(inten)
        cum = jnp.cumsum(sorted_i)
        is_lat_sorted = cum <= cfg.tcm_lat_frac * total
        new_is_lat = is_lat_sorted[order]
        # ranks: latency cluster by ascending intensity; bw cluster shuffled
        shuf = buf["shuffle"] + 1
        lat_rank = order
        bw_rank = jnp.mod(order + shuf, S)
        new_rank = jnp.where(new_is_lat, lat_rank, bw_rank)
        buf["tcm_is_lat"] = new_is_lat
        buf["tcm_rank"] = new_rank
        buf["served_quant"] = jnp.zeros_like(buf["served_quant"])
        buf["shuffle"] = shuf
        buf["pri_src"] = new_is_lat.astype(jnp.int32) * POL_BIT + \
            ((S - new_rank).astype(jnp.int32) << RANK_SHIFT)
        return buf

    def on_issue(self, cfg, pool, buf, do, pick, src, t):
        buf = dict(buf)
        buf["served_quant"] = engine.accum_by_index(
            buf["served_quant"], src, 1.0, do)
        return buf

    def next_boundary(self, cfg, pool, st, buf, t):
        # the shuffle counter advances every quantum even when idle
        return jnp.int32((t // cfg.tcm_quantum + 1) * cfg.tcm_quantum)
