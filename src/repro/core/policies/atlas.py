"""ATLAS (Kim et al., HPCA'10): rank sources by least attained service,
recomputed every epoch with exponential decay.

The attained-service totals only change at epoch boundaries, so the
ranking argsort lives in `boundary_tick` behind a `lax.cond` on the scalar
cycle counter — between epochs `score` is just a gather of the cached
per-source priority.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.schedulers import CentralizedPolicy, RANK_SHIFT, rank_pos


@policy.register
class ATLAS(CentralizedPolicy):
    name = "atlas"
    boundary_keys = ("attained", "served_epoch", "pri_src")
    # stacked schema: (S,) attained/served_epoch/pri_src; tick writes are
    # boundary-only (the default), on_issue maintains the service counter
    stacked_issue_keys = ("served_epoch",)

    def extra_state(self, cfg):
        S = cfg.n_src
        return {
            "attained": jnp.zeros((S,), jnp.float32),
            "served_epoch": jnp.zeros((S,), jnp.float32),
            "pri_src": jnp.zeros((S,), jnp.int32),
        }

    def boundary_pred(self, cfg, pool, st, buf, t):
        return jnp.mod(t, cfg.atlas_epoch) == 0

    def boundary_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        S = cfg.n_src
        att = cfg.atlas_alpha * buf["attained"] + buf["served_epoch"]
        buf["attained"] = att
        buf["served_epoch"] = jnp.zeros_like(buf["served_epoch"])
        rank = rank_pos(att)                            # 0 = least attained
        buf["pri_src"] = (S - rank).astype(jnp.int32) << RANK_SHIFT
        return buf

    def on_issue(self, cfg, pool, buf, do, pick, src, t):
        buf = dict(buf)
        buf["served_epoch"] = engine.accum_by_index(
            buf["served_epoch"], src, 1.0, do)
        return buf

    def next_boundary(self, cfg, pool, st, buf, t):
        # epoch boundaries always run (the decay changes `attained` even in
        # an idle epoch), so the witness is the next epoch multiple
        return jnp.int32((t // cfg.atlas_epoch + 1) * cfg.atlas_epoch)
