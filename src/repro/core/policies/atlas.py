"""ATLAS (Kim et al., HPCA'10): rank sources by least attained service,
recomputed every epoch with exponential decay."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import policy
from repro.core.schedulers import (CentralizedPolicy, RANK_SHIFT, base_score,
                                   rank_pos)


@policy.register
class ATLAS(CentralizedPolicy):
    name = "atlas"

    def extra_state(self, cfg):
        S = cfg.n_src
        return {
            "attained": jnp.zeros((S,), jnp.float32),
            "served_epoch": jnp.zeros((S,), jnp.float32),
        }

    def policy_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        epoch = jnp.mod(t, cfg.atlas_epoch) == 0
        att = cfg.atlas_alpha * buf["attained"] + buf["served_epoch"]
        buf["attained"] = jnp.where(epoch, att, buf["attained"])
        buf["served_epoch"] = jnp.where(epoch, 0.0, buf["served_epoch"])
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        S = cfg.n_src
        rank = rank_pos(buf["attained"])                # 0 = least attained
        pri = (S - rank[buf["src"]]).astype(jnp.int32) << RANK_SHIFT
        return pri + base_score(cfg, buf, is_hit, t)

    def on_issue(self, cfg, pool, buf, do, src, t):
        buf = dict(buf)
        safe = jnp.where(do, src, 0)
        buf["served_epoch"] = buf["served_epoch"].at[safe].add(
            do.astype(jnp.float32))
        return buf
