"""SMS and SMS-DASH as registered `MemoryPolicy` objects.

The staged machinery lives in `repro.core.sms`; this module binds it to the
protocol. SMS-DASH is a *knob-point variant* — same stages, with the
deadline-aware stage-2 preemption pinned on via `configure_knobs` (the
`dash` value knob) — so it rides the registry instead of forking a second
config: `configure` stays the identity, and a knob grid can sweep `dash`
on plain "sms" without touching the registry at all.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import policy, sms as sms_lib


@policy.register
class SMS:
    name = "sms"
    variant_of = None
    # staged FIFO/DCS state shares nothing with the centralized CAM-buffer
    # schema — SMS-style protocols run the per-policy path
    stackable = False

    def configure(self, cfg):
        return cfg

    def init_state(self, cfg):
        return sms_lib.sms_state(cfg)

    def tick(self, cfg, pool, st, sched, t):
        st, sched = sms_lib.stage1_admit(cfg, st, sched, t)
        st, sched = sms_lib.stage2_drain(cfg, pool, st, sched, t)
        return st, sched

    def select(self, cfg, pool, st, sched, dram, t):
        return sms_lib.stage3_issue(cfg, st, sched, dram, t)

    # -- variable-step driver witness (see `policy.make_skip_step`) ---------
    def next_event(self, cfg, pool, st, sched, dram, t):
        return sms_lib.next_stage_event(cfg, st, sched, dram, t)

    def on_skip(self, cfg, sched, k):
        return sms_lib.skip_cycles(sched, k)

    # -- invariant-sanitizer hooks (repro.core.validate) --------------------
    def queued_requests(self, cfg, sched):
        return jnp.sum(sched["f_len"]) + jnp.sum(sched["d_len"])

    def check_invariants(self, cfg, pool, st, sched, t):
        return sms_lib.check_invariants(cfg, sched, t)

    def audit_skip(self, cfg, pool, st, sched, dram, t, t_new):
        return sms_lib.audit_skip(cfg, st, sched, dram, t, t_new)


@policy.register
class SMSDash(SMS):
    name = "sms_dash"
    variant_of = "sms"

    def configure_knobs(self, knobs):
        # SMS + deadline-aware stage 2 (paper §7 extension): dash is a
        # value knob, pinned True for this registry entry
        return knobs.replace(dash=True)
