"""SQUASH-style probabilistic prioritization (Usui et al.,
arXiv:1505.07502).

SQUASH schedules hardware accelerators by *probabilistically* raising their
priority over the cores so they meet frame deadlines without monopolizing
the bus. This variant redraws a per-source priority bit every
`squash_epoch` cycles:

  * deadline sources behind their frame pace (plus a `squash_lead` cycle
    margin) are *urgent*: a priority tier above everything else, tracked
    every cycle (the paper's urgent state), and their pending requests jump
    the admission queue;
  * on-pace deadline sources win the probabilistic draw with `squash_pb`;
  * the GPU wins with prob `squash_gpu_pb` (throughput is its own reward);
  * CPUs win with prob `squash_cpu_pb`, keeping latency-sensitive cores
    regularly boosted above the streaming sources.

Within a priority tier, FR-FCFS (row-hit then age) breaks ties, so nothing
can starve: age keeps rising for never-boosted sources.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.params import CLS_GPU, CLS_HWA
from repro.core.schedulers import CentralizedPolicy, POL_BIT

URGENT_BIT = POL_BIT << 1


@policy.register
class SquashPrio(CentralizedPolicy):
    name = "squash_prio"
    boundary_keys = ("sq_rng", "sq_prio")
    # stacked schema: (S,) rng/priority/urgency; the per-cycle policy_tick
    # writes sq_urgent + pri_src on top of the boundary draw, on_admit
    # accumulates the urgent-admission QoS counter
    stacked_tick_keys = boundary_keys + ("sq_urgent", "pri_src",
                                         "sq_urgent_adm")

    def extra_state(self, cfg):
        S = cfg.n_src
        return {
            "sq_prio": jnp.zeros((S,), bool),
            "sq_urgent": jnp.zeros((S,), bool),
            "sq_rng": (jnp.arange(S, dtype=jnp.uint32) * jnp.uint32(747796405)
                       + jnp.uint32(2891336453)),
            "pri_src": jnp.zeros((S,), jnp.int32),
            # admissions that jumped the queue on the urgent tier, per
            # source (QoS accounting only; surfaced as `urgent_admits`)
            "sq_urgent_adm": jnp.zeros((S,), jnp.int32),
        }

    def boundary_pred(self, cfg, pool, st, buf, t):
        return jnp.mod(t, cfg.squash_epoch) == 0

    def boundary_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        is_accel = pool["src_class"] == CLS_HWA
        rng, u = engine.lcg_step(buf["sq_rng"])
        p = jnp.where(is_accel, cfg.squash_pb,
                      jnp.where(pool["src_class"] == CLS_GPU,
                                cfg.squash_gpu_pb, cfg.squash_cpu_pb))
        buf["sq_rng"] = rng
        buf["sq_prio"] = u < p
        return buf

    def on_admit(self, cfg, pool, st, buf, do, slot, src, t):
        buf = dict(buf)
        buf["sq_urgent_adm"] = engine.accum_by_index(
            buf["sq_urgent_adm"], src, 1, do & buf["sq_urgent"][src])
        return buf

    def policy_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        # urgency needs both the HWA class AND a live deadline stream
        is_accel = (pool["src_class"] == CLS_HWA) & (pool["dl_period"] > 0)
        # urgent until ahead of the linear frame pace by squash_lead cycles:
        # done/reqs < (phase + lead)/period. (A lead keeps the source from
        # asymptotically tracking the pace line and missing by a hair; a
        # permanently-urgent slack rule floods its own bank queue and does
        # worse — measured in benchmarks/dash_deadline.) Urgency is
        # per-cycle state (the paper's urgent bit), so it lives here, not
        # in the epoch-gated boundary.
        phase = jnp.mod(t, jnp.maximum(pool["dl_period"], 1))
        remaining = jnp.maximum(pool["dl_reqs"] - st["period_done"], 0)
        buf["sq_urgent"] = is_accel & (remaining > 0) & \
            (st["period_done"] * pool["dl_period"]
             < (phase + cfg.squash_lead) * pool["dl_reqs"])
        buf["pri_src"] = buf["sq_urgent"].astype(jnp.int32) * URGENT_BIT + \
            buf["sq_prio"].astype(jnp.int32) * POL_BIT
        return buf

    def admit_key(self, cfg, pool, st, buf, t):
        # urgency reaches the admission port too: an urgent source's pending
        # request admits ahead of anything merely older
        return st["pend_birth"] - jnp.where(buf["sq_urgent"],
                                            jnp.int32(1 << 20), 0)

    def next_boundary(self, cfg, pool, st, buf, t):
        # `policy_tick` runs every cycle, so a span may only skip cycles
        # where its writes are fixed points: between epoch draws, urgency is
        # monotone within a frame (`period_done`/`remaining` are frozen
        # until a witnessed completion or frame boundary while the pace RHS
        # grows with phase), so the only time-driven change is the first
        # phase at which a currently-non-urgent deadline source flips on.
        nb = jnp.int32((t // cfg.squash_epoch + 1) * cfg.squash_epoch)
        is_accel = (pool["src_class"] == CLS_HWA) & (pool["dl_period"] > 0)
        period = jnp.maximum(pool["dl_period"], 1)
        reqs = jnp.maximum(pool["dl_reqs"], 1)
        remaining = jnp.maximum(pool["dl_reqs"] - st["period_done"], 0)
        # smallest integer phase with done*period < (phase + lead)*reqs
        phase_on = jnp.floor_divide(
            st["period_done"] * pool["dl_period"] - cfg.squash_lead * reqs,
            reqs) + 1
        tau = (t - jnp.mod(t, period)) + phase_on
        cand = is_accel & (remaining > 0) & ~buf["sq_urgent"]
        w_flip = jnp.min(jnp.where(cand, jnp.maximum(tau, t + 1),
                                   jnp.int32(engine.INF_T)))
        return jnp.minimum(nb, w_flip)
