"""FR-FCFS: row hits first, then oldest (Rixner et al.). The baseline the
paper starts from — maximal row-buffer locality, no source awareness, and
therefore the GPU-favoring unfairness of Fig 1. The inherited `score` is
exactly the FR-FCFS base score (no cached priority slot)."""
from __future__ import annotations

from repro.core import policy
from repro.core.schedulers import CentralizedPolicy


@policy.register
class FRFCFS(CentralizedPolicy):
    name = "frfcfs"
    # stacked (the CentralizedPolicy default): contributes no extra state;
    # hooks write nothing, so both stacked write-sets stay empty. Under the
    # padded union schema the zero `pri_src` from ranked siblings adds 0 to
    # the default score — bit-identical to the standalone path.
