"""FR-FCFS: row hits first, then oldest (Rixner et al.). The baseline the
paper starts from — maximal row-buffer locality, no source awareness, and
therefore the GPU-favoring unfairness of Fig 1."""
from __future__ import annotations

from repro.core import policy
from repro.core.schedulers import CentralizedPolicy, base_score


@policy.register
class FRFCFS(CentralizedPolicy):
    name = "frfcfs"

    def score(self, cfg, pool, buf, is_hit, t):
        return base_score(cfg, buf, is_hit, t)
