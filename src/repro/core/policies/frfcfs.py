"""FR-FCFS: row hits first, then oldest (Rixner et al.). The baseline the
paper starts from — maximal row-buffer locality, no source awareness, and
therefore the GPU-favoring unfairness of Fig 1. The inherited `score` is
exactly the FR-FCFS base score (no cached priority slot)."""
from __future__ import annotations

from repro.core import policy
from repro.core.schedulers import CentralizedPolicy


@policy.register
class FRFCFS(CentralizedPolicy):
    name = "frfcfs"
