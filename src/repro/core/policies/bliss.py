"""BLISS — the Blacklisting memory scheduler (Subramanian et al.,
arXiv:1504.00390).

Instead of a full application ranking, each channel watches the stream of
issued requests: a source served `bliss_threshold` times consecutively is
"interference-causing" and gets blacklisted. Scheduling is then just
non-blacklisted > row-hit > age, and the blacklist is wiped every
`bliss_clear_interval` cycles so sources are only penalized while they are
actually streaming. State is ~20 lines: one (C,) last-served id, one (C,)
streak counter, one (S,) blacklist bit-vector.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import policy
from repro.core.schedulers import CentralizedPolicy, POL_BIT, base_score


@policy.register
class BLISS(CentralizedPolicy):
    name = "bliss"

    def extra_state(self, cfg):
        C, S = cfg.n_channels, cfg.n_src
        return {
            "bl_last": jnp.full((C,), -1, jnp.int32),
            "bl_streak": jnp.zeros((C,), jnp.int32),
            "blacklist": jnp.zeros((S,), bool),
        }

    def policy_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        clear = jnp.mod(t, cfg.bliss_clear_interval) == 0
        buf["blacklist"] = jnp.where(clear, False, buf["blacklist"])
        return buf

    def score(self, cfg, pool, buf, is_hit, t):
        ok = ~buf["blacklist"][buf["src"]]              # (C, E)
        return ok.astype(jnp.int32) * POL_BIT + \
            base_score(cfg, buf, is_hit, t)

    def on_issue(self, cfg, pool, buf, do, src, t):
        buf = dict(buf)
        same = do & (src == buf["bl_last"])
        streak = jnp.where(do, jnp.where(same, buf["bl_streak"] + 1, 1),
                           buf["bl_streak"])
        over = do & (streak >= cfg.bliss_threshold)
        buf["bl_last"] = jnp.where(do, src, buf["bl_last"])
        buf["bl_streak"] = jnp.where(over, 0, streak)
        buf["blacklist"] = buf["blacklist"].at[
            jnp.where(over, src, cfg.n_src)].set(True, mode="drop")
        return buf
