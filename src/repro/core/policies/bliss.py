"""BLISS — the Blacklisting memory scheduler (Subramanian et al.,
arXiv:1504.00390).

Instead of a full application ranking, each channel watches the stream of
issued requests: a source served `bliss_threshold` times consecutively is
"interference-causing" and gets blacklisted. Scheduling is then just
non-blacklisted > row-hit > age, and the blacklist is wiped every
`bliss_clear_interval` cycles (a `boundary_tick` cond on the scalar cycle
counter) so sources are only penalized while they are actually streaming.
State is ~20 lines: one (C,) last-served id, one (C,) streak counter, one
(S,) blacklist bit-vector mirrored into the cached `pri_src` priority.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import policy
from repro.core.schedulers import CentralizedPolicy, POL_BIT


@policy.register
class BLISS(CentralizedPolicy):
    name = "bliss"
    boundary_keys = ("blacklist", "pri_src")
    # stacked schema: (C,) streak trackers + (S,) blacklist/pri_src; the
    # whole blacklisting state machine lives in on_issue
    stacked_issue_keys = ("bl_last", "bl_streak", "blacklist", "pri_src")

    def extra_state(self, cfg):
        C, S = cfg.n_channels, cfg.n_src
        return {
            "bl_last": jnp.full((C,), -1, jnp.int32),
            "bl_streak": jnp.zeros((C,), jnp.int32),
            "blacklist": jnp.zeros((S,), bool),
            "pri_src": jnp.full((S,), POL_BIT, jnp.int32),
        }

    def boundary_pred(self, cfg, pool, st, buf, t):
        return jnp.mod(t, cfg.bliss_clear_interval) == 0

    def boundary_tick(self, cfg, pool, st, buf, t):
        buf = dict(buf)
        buf["blacklist"] = jnp.zeros_like(buf["blacklist"])
        buf["pri_src"] = jnp.full_like(buf["pri_src"], POL_BIT)
        return buf

    def on_issue(self, cfg, pool, buf, do, pick, src, t):
        buf = dict(buf)
        same = do & (src == buf["bl_last"])
        streak = jnp.where(do, jnp.where(same, buf["bl_streak"] + 1, 1),
                           buf["bl_streak"])
        over = do & (streak >= cfg.bliss_threshold)
        buf["bl_last"] = jnp.where(do, src, buf["bl_last"])
        buf["bl_streak"] = jnp.where(over, 0, streak)
        hit = jnp.any((jnp.arange(cfg.n_src) == src[:, None]) &
                      over[:, None], axis=0)
        buf["blacklist"] = buf["blacklist"] | hit
        buf["pri_src"] = (~buf["blacklist"]).astype(jnp.int32) * POL_BIT
        return buf

    def next_boundary(self, cfg, pool, st, buf, t):
        # the streak machine lives entirely in on_issue (issues are
        # witnessed); only the interval clear is time-driven
        return jnp.int32((t // cfg.bliss_clear_interval + 1)
                         * cfg.bliss_clear_interval)
