"""Built-in `MemoryPolicy` implementations, one module per policy.

Importing this package registers every built-in with
`repro.core.policy.POLICY_REGISTRY`; registration order fixes the order of
`simulator.POLICIES` / `ALL_POLICIES` and of every benchmark sweep.
"""
from repro.core.policies import frfcfs    # noqa: F401
from repro.core.policies import atlas     # noqa: F401
from repro.core.policies import parbs     # noqa: F401
from repro.core.policies import tcm       # noqa: F401
from repro.core.policies import sms       # noqa: F401
from repro.core.policies import bliss     # noqa: F401
from repro.core.policies import squash    # noqa: F401
