"""Static simulation parameters: DRAM timing, structure sizes, policy knobs.

Timing values are DDR3-1600-class, expressed in memory-controller cycles
(the paper's simulator granularity). The request lifecycle model is
Ramulator-lite: a scheduled request occupies its bank for the access latency
and the shared per-channel data bus for tBURST; non-hits count as ACTIVATEs
against the per-channel tFAW window.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# requester classes (the N-class source model). A source's `src_class` picks
# its traffic generator and its reporting bucket: CPU cores are
# latency-sensitive MLP-limit cores, the GPU is a streaming wavefront
# generator, HWAs are frame-deadline accelerators (SQUASH-style periodic
# bursts). Extending the model = append a name here, teach
# `engine.source_tick` the generator, and add archetypes in `workloads`
# (see ROADMAP "Requester classes").
# ---------------------------------------------------------------------------
CLS_CPU, CLS_GPU, CLS_HWA = 0, 1, 2
CLASS_NAMES: Tuple[str, ...] = ("cpu", "gpu", "hwa")
N_CLASSES = len(CLASS_NAMES)


@dataclass(frozen=True)
class Timing:
    t_rcd: int = 11      # ACT -> READ
    t_rp: int = 11       # PRE
    t_cas: int = 11      # READ -> data
    t_ras: int = 28      # ACT -> PRE (folded into busy window)
    t_faw: int = 32      # four-ACT window
    t_burst: int = 4     # data burst on the bus

    @property
    def lat_hit(self) -> int:
        return self.t_cas

    @property
    def lat_conflict(self) -> int:          # open row, wrong row
        return self.t_rp + self.t_rcd + self.t_cas

    @property
    def lat_closed(self) -> int:            # bank closed
        return self.t_rcd + self.t_cas


@dataclass(frozen=True)
class SimConfig:
    """Static config (shapes are baked into the jitted step)."""

    n_cpu: int = 8
    n_gpu: int = 1
    n_hwa: int = 0                   # frame-deadline accelerators (CLS_HWA)
    n_channels: int = 1
    n_banks: int = 8                 # banks per channel
    n_rows: int = 4096               # rows per bank (address space)

    # centralized request buffer (per channel); SMS uses fifo/dcs sizes below
    buf_entries: int = 64
    cpu_reserve: float = 0.5         # fraction of entries GPU may NOT occupy

    # SMS structures (per channel)
    fifo_size: int = 16              # stage-1 per-source FIFO
    dcs_size: int = 12               # stage-3 per-bank FIFO
    batch_age_cap: int = 200         # stage-1 age threshold
    sjf_prob: float = 0.9            # stage-2 SJF probability p

    # cores
    cpu_ipc: float = 2.0             # 3-wide OoO effective IPC between misses
    cpu_mshr: int = 8
    gpu_mshr: int = 128              # wavefront-scale outstanding requests
    hwa_mshr: int = 128              # accelerator outstanding-request bound
                                     # (frame bursts are dl_reqs-gated anyway)

    # policy knobs
    atlas_alpha: float = 0.875
    atlas_epoch: int = 2000
    parbs_cap: int = 5
    tcm_quantum: int = 1000
    tcm_lat_frac: float = 0.25       # fraction of bandwidth for latency cluster
    # BLISS (Subramanian et al., arXiv:1504.00390)
    bliss_threshold: int = 4         # consecutive serves before blacklisting
    bliss_clear_interval: int = 10_000
    # SQUASH-style probabilistic prioritization (Usui et al., 1505.07502)
    squash_epoch: int = 100          # priority redraw interval (short, so
                                     # mid-frame pace deficits are caught)
    squash_lead: int = 150           # cycles of pace headroom a deadline
                                     # source must bank before urgency clears
    squash_pb: float = 0.75          # on-pace deadline source boost prob
    squash_gpu_pb: float = 0.15      # GPU boost prob
    squash_cpu_pb: float = 0.35      # CPU boost prob
    # SMS-DASH (paper §7 future work, after Usui et al. [201,202]):
    # deadline-aware stage-2 — urgent accelerator batches preempt SJF/RR
    dash: bool = False
    dash_svc_est: float = 24.0       # estimated cycles per request (slack
                                     # calc; conservative => earlier urgency)
    # DRAM energy accounting (repro.core.energy): per-command energies in
    # nJ at DDR3-1600-class scale (Micron power-calc ballpark), background
    # power per channel-cycle. Energy-only — never feeds back into timing
    # or scheduling, so flipping `energy_enabled` cannot change decisions.
    energy_enabled: bool = True
    energy_act: float = 2.5          # ACT+PRE pair, charged per row miss
    energy_rw: float = 1.2           # RD/WR burst, charged per issue
    energy_standby: float = 0.10     # active-standby, per channel-cycle
    energy_pd: float = 0.025         # power-down, per channel-cycle
    energy_wake: float = 0.8         # power-down exit penalty, per wake
    energy_pd_idle: int = 48         # all-banks-idle cycles before power-down
    # per-class QoS accounting (repro.core.qos): a per-source request-latency
    # histogram maintained at issue time. Measurement-only, same contract as
    # energy: flipping `qos_enabled` cannot change a scheduling decision.
    qos_enabled: bool = True
    # per-cycle invariant sanitizer (repro.core.validate): DRAM timing
    # compliance, conservation laws, and skip-witness lateness audits,
    # accumulated as int32 violation counters in dram_state. Measurement-
    # only like energy/qos — flipping `validate_enabled` cannot change a
    # scheduling decision, and OFF adds zero primitives to the hot loop.
    validate_enabled: bool = False
    lat_bins: int = 32               # histogram bins per source
    lat_bin_width: int = 64          # cycles per bin (last bin open-ended):
                                     # 2048-cycle range covers the queueing
                                     # tails that p99 actually lives in
    # windowed flight recorder (repro.core.telemetry): a (W, K) ring of
    # epoch-downsampled time-series channels in dram_state. Measurement-
    # only like energy/qos/validate — flipping `telemetry_enabled` cannot
    # change a scheduling decision, and OFF adds zero primitives to the
    # hot loop. Window/epoch set ARRAY SHAPES, so they are static config
    # fields (like lat_bins), never value knobs.
    telemetry_enabled: bool = False
    telemetry_window: int = 32       # ring slots (last W epochs retained)
    telemetry_epoch: int = 256       # cycles per epoch (downsample factor)
    timing: Timing = Timing()

    @property
    def n_src(self) -> int:
        return self.n_cpu + self.n_gpu + self.n_hwa

    @property
    def gpu_cap(self) -> int:
        return max(1, int(self.buf_entries * (1.0 - self.cpu_reserve)))

    def replace(self, **kw) -> "SimConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Tunable knobs (ROADMAP "Tunable knobs contract"). SimConfig stays the
# single source of defaults, but the simulator never reads a VALUE-LIKE knob
# off it directly: `simulator._init` lifts them into a `Knobs` pytree and
# hands hooks a `bind(cfg, knobs)` view. Because Knobs leaves are jax
# arrays, a knob sweep can ride a vmapped variant axis through ONE compiled
# program instead of recompiling per point.
#
# Two knob classes, split by how they enter the trace:
#   * VALUE-LIKE (KNOB_SPECS): probabilities, caps, thresholds, fractions —
#     consumed as jnp operands, so traced/batched values flow through
#     unchanged.
#   * PERIOD-LIKE (PERIOD_KNOBS): epoch/quantum/interval lengths feeding
#     t-only boundary predicates and `next_boundary` witnesses. These MUST
#     stay trace-time Python ints (a traced period would batch the
#     predicate, dissolving the nested boundary `lax.cond` under vmap —
#     same reasoning as the stacked-path rule against `lax.switch` on a
#     batched index), so grids vary them per slice via `cfg.replace`.
# ---------------------------------------------------------------------------

KNOB_SPECS: Tuple[Tuple[str, Any], ...] = (
    ("cpu_reserve", jnp.float32),
    ("batch_age_cap", jnp.int32),
    ("sjf_prob", jnp.float32),
    ("atlas_alpha", jnp.float32),
    ("parbs_cap", jnp.int32),
    ("tcm_lat_frac", jnp.float32),
    ("bliss_threshold", jnp.int32),
    ("squash_lead", jnp.int32),
    ("squash_pb", jnp.float32),
    ("squash_gpu_pb", jnp.float32),
    ("squash_cpu_pb", jnp.float32),
    ("dash", jnp.bool_),
    ("dash_svc_est", jnp.float32),
    ("energy_pd_idle", jnp.int32),
)
KNOB_FIELDS: Tuple[str, ...] = tuple(n for n, _ in KNOB_SPECS)
PERIOD_KNOBS: Tuple[str, ...] = ("atlas_epoch", "tcm_quantum",
                                 "squash_epoch", "bliss_clear_interval")
_KNOB_SET = frozenset(KNOB_FIELDS)


@dataclass(frozen=True)
class Knobs:
    """The tunable-value half of a SimConfig, as a jax pytree.

    Leaves carry canonical dtypes (so a default-knob trace emits the same
    f32/i32 constants the old Python literals did — golden digests pinned)
    and may be traced or batched. Build with `Knobs.from_cfg`.
    """

    cpu_reserve: Any
    batch_age_cap: Any
    sjf_prob: Any
    atlas_alpha: Any
    parbs_cap: Any
    tcm_lat_frac: Any
    bliss_threshold: Any
    squash_lead: Any
    squash_pb: Any
    squash_gpu_pb: Any
    squash_cpu_pb: Any
    dash: Any
    dash_svc_est: Any
    energy_pd_idle: Any

    @classmethod
    def from_cfg(cls, cfg: "SimConfig", **overrides) -> "Knobs":
        """Knobs at `cfg`'s values, with optional value-knob overrides.

        Period-like knobs are rejected with a pointer to the per-slice
        path (`cfg.replace` / `simulate_stacked_grid`)."""
        bad_period = sorted(set(overrides) & set(PERIOD_KNOBS))
        if bad_period:
            raise ValueError(
                f"period-like knobs {bad_period} cannot batch (they gate "
                f"t-only boundary conds); vary them per slice via "
                f"cfg.replace / simulate_stacked_grid")
        bad = sorted(set(overrides) - _KNOB_SET)
        if bad:
            raise ValueError(f"not tunable value knobs: {bad}; "
                             f"known: {sorted(_KNOB_SET)}")
        vals = {n: overrides.get(n, getattr(cfg, n)) for n in KNOB_FIELDS}
        return cls(**{n: jnp.asarray(v, dt) for (n, dt), v
                      in zip(KNOB_SPECS, vals.values())})

    def replace(self, **kw) -> "Knobs":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_pytree_node(
    Knobs,
    lambda k: (tuple(getattr(k, f) for f in KNOB_FIELDS), None),
    lambda _, leaves: Knobs(*leaves))


def stack_knobs(points: Sequence[Knobs]) -> Knobs:
    """Stack knob points on a leading variant axis (for the grid vmap)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *points)


def split_overrides(overrides: Dict[str, Any]):
    """Split a mixed override dict into (period-like, value-like) parts."""
    per = {k: v for k, v in overrides.items() if k in PERIOD_KNOBS}
    val = {k: v for k, v in overrides.items() if k in _KNOB_SET}
    bad = sorted(set(overrides) - set(per) - set(val))
    if bad:
        raise ValueError(f"not tunable knobs: {bad}")
    return per, val


def static_bool(x) -> Any:
    """Concrete truth value of a knob, or None when it is traced.

    Lets code keep a Python branch for statically-off features (identical
    trace to the pre-Knobs literals) while falling back to masking when the
    knob is genuinely batched."""
    try:
        return bool(x)
    except Exception:
        return None


class BoundConfig:
    """A SimConfig view with value-like knobs served from a `Knobs` pytree.

    Everything shape-/period-/timing-like delegates to the underlying
    SimConfig (trace-time Python values); the value knobs come from the
    bound Knobs (possibly traced arrays). `gpu_cap` is recomputed from the
    bound `cpu_reserve` (trunc == floor for the non-negative operand, so a
    concrete default reproduces SimConfig.gpu_cap exactly).
    """

    __slots__ = ("_cfg", "_knobs")

    def __init__(self, cfg: SimConfig, knobs: Knobs):
        object.__setattr__(self, "_cfg", cfg)
        object.__setattr__(self, "_knobs", knobs)

    def __setattr__(self, name, value):
        raise AttributeError("BoundConfig is read-only")

    def __getattr__(self, name):
        if name in _KNOB_SET:
            return getattr(self._knobs, name)
        return getattr(self._cfg, name)

    @property
    def base(self) -> SimConfig:
        return self._cfg

    @property
    def knobs(self) -> Knobs:
        return self._knobs

    @property
    def gpu_cap(self):
        cap = (jnp.float32(self._cfg.buf_entries)
               * (1.0 - self._knobs.cpu_reserve)).astype(jnp.int32)
        return jnp.maximum(jnp.int32(1), cap)

    def __repr__(self):
        return f"BoundConfig({self._cfg!r}, {self._knobs!r})"


def bind(cfg: SimConfig, knobs: Knobs) -> BoundConfig:
    """The config view the simulator hands to hooks: cfg + live knobs."""
    if isinstance(cfg, BoundConfig):
        cfg = cfg.base
    return BoundConfig(cfg, knobs)


@dataclass(frozen=True)
class SourcePool:
    """Per-source trace parameters, as numpy arrays of len n_src.

    CPU sources follow an MLP-limit core model (MSHR-bounded outstanding
    misses, geometric inter-miss instruction gaps). The GPU source is a
    wavefront-style generator: effectively unbounded queue of requests with
    high row-buffer locality striped across `blp` banks (Fig 1 calibration).
    """

    mpki: np.ndarray        # CPU memory intensity (LLC MPKI); GPU ignores
    rbl: np.ndarray         # P(next request same (bank,row))
    blp: np.ndarray         # bank-level parallelism (stripe width)
    is_gpu: np.ndarray      # bool
    # real-time accelerator sources (SMS-DASH): need dl_reqs requests
    # completed every dl_period cycles (0 = no deadline)
    dl_period: np.ndarray = None
    dl_reqs: np.ndarray = None
    # N-class keys. When absent the simulator derives them (see
    # `simulator.prepare_pool`): src_class from is_gpu/dl_period, jitter 0 —
    # so legacy 2-class pools run bit-identically.
    src_class: np.ndarray = None    # CLS_* id per source
    dl_jitter: np.ndarray = None    # max per-frame release jitter, cycles

    def inst_per_miss(self) -> np.ndarray:
        return np.maximum(1000.0 / np.maximum(self.mpki, 1e-3), 1.0)
