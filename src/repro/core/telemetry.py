"""Windowed flight recorder: time-resolved counters inside the hot loop.

The paper's claims are *dynamic* — GPU/HWA bursts starve CPU cores until
the staged design smooths them (§4) — but end-of-run aggregates average
those episodes away. This module keeps a `(W, K)` ring of epoch-downsampled
channels in `dram_state`: cycle time is split into epochs of
`cfg.telemetry_epoch` cycles, epoch `e` accumulates into ring slot
`e % cfg.telemetry_window`, and a slot is zeroed exactly when it starts
representing a newer epoch. The final ring therefore holds the last W
epochs of the run — a flight recorder, not a full trace — at O(W*K) state
independent of run length.

Channels (`CHANNELS` order; all int32 accumulators, zero-init):

  occ_*        sum over cycles of end-of-cycle in-flight requests per
               class (divide by epoch width for mean queue depth; by
               Little's law occ/issue-rate is a latency proxy);
  adm_*        admissions per class (pending register consumed);
  iss_*        DRAM issues per class;
  row_hits     row-hit issues (all classes; hits/issues = hit rate);
  batch_marks  newly marked batch entries in the centralized buffer
               (PAR-BS/BLISS-style marking; 0 for SMS, whose staged
               batches are visible through occ/iss instead);
  pd_chan      sum over cycles of channels in power-down at end of cycle
               (residency; requires `energy_enabled`, else 0);
  steps        processed driver steps — the skip meter. Every channel
               BEFORE this one is driver-invariant (ticked and
               variable-step runs produce bit-identical values); `steps`
               is a driver property like `sim_steps` and is deliberately
               last so comparisons can slice it off.

Contract (ROADMAP "Telemetry contract", same shape as energy/validate):
gated by static `cfg.telemetry_enabled` — OFF adds zero primitives to the
per-cycle jaxpr (the state dict is empty and every call site is a Python
branch); ON never feeds a value back into admission, scoring, or timing,
so golden digests stay bit-identical. Span-exact: the variable-step driver
charges a whole skipped span with `skip_accrue` below — frozen occupancy
and the closed-form power-down split, the same integer-counter argument as
`energy.skip_accrue` — so no new witnesses are needed and ticked vs
skipping rings agree bit-for-bit (minus `steps`). All accumulation is
one-hot masked adds on static (W, K) shapes; zero is a safe padding value,
so the ring rides the stacked carry and the grid paths unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.params import N_CLASSES, SimConfig

CHANNELS = (
    "occ_cpu", "occ_gpu", "occ_hwa",
    "adm_cpu", "adm_gpu", "adm_hwa",
    "iss_cpu", "iss_gpu", "iss_hwa",
    "row_hits", "batch_marks", "pd_chan",
    "steps",
)
K = len(CHANNELS)
CH = {name: i for i, name in enumerate(CHANNELS)}
# channels below this index are driver-invariant; `steps` is the skip meter
N_INVARIANT = CH["steps"]

# dram_state keys owned by this module (golden-digest whitelists)
STATE_KEYS = ("tl_ring", "tl_epoch")


def telemetry_state(cfg: SimConfig) -> Dict[str, Any]:
    """Flight-recorder state merged into `engine.dram_state` when enabled.

    tl_ring: (W, K) channel accumulators; tl_epoch: the newest epoch the
    ring has been advanced to (scalar). Zero-init doubles as safe padding.
    """
    if not cfg.telemetry_enabled:
        return {}
    return {
        "tl_ring": jnp.zeros((cfg.telemetry_window, K), jnp.int32),
        "tl_epoch": jnp.zeros((), jnp.int32),
    }


def _slot_epochs(W: int, e):
    """The newest epoch <= e that each of the W ring slots represents:
    slot s holds epoch e - ((e - s) mod W). Uniform in e, so advancing the
    ring from any epoch to any later epoch — ticked increments and
    arbitrary span jumps alike — is the same one formula."""
    s = jnp.arange(W, dtype=jnp.int32)
    return e - jnp.mod(e - s, W)


def _advance(W: int, ring, e_old, e_new):
    """Zero every slot whose represented epoch moved past its old one."""
    stale = _slot_epochs(W, e_new) > _slot_epochs(W, e_old)
    return jnp.where(stale[:, None], 0, ring)


def _class_sums(cls, v):
    """(S,) int values -> (N_CLASSES,) per-class sums (one-hot masked)."""
    v = v.astype(jnp.int32)
    return jnp.stack([jnp.sum(jnp.where(cls == c, v, 0))
                      for c in range(N_CLASSES)])


def snapshot(st, sched, dram) -> Dict[str, Any]:
    """Pre-step counter snapshot; post-step deltas yield this cycle's
    events without touching `engine.issue_channels` or any policy hook."""
    snap = {
        "emitted": st["emitted"],
        "pend_valid": st["pend_valid"],
        "issued": dram["issued"],
        "hits": dram["hits"],
    }
    if "marked" in sched:
        snap["marked"] = sched["marked"]
    return snap


def tick_accrue(cfg: SimConfig, pool, snap, st, sched, dram, t
                ) -> Dict[str, Any]:
    """Charge cycle t's end-of-cycle values into the ring (one-hot add).

    Runs after the policy's select — occupancy/power-down are end-of-cycle
    samples, event channels are post-minus-pre deltas against `snap`.
    """
    W, E = cfg.telemetry_window, cfg.telemetry_epoch
    e = (t // E).astype(jnp.int32)
    ring = _advance(W, dram["tl_ring"], dram["tl_epoch"], e)
    cls = pool["src_class"]
    # admission = pending register consumed: it was (or became) valid this
    # cycle and is no longer; at most one emission per source per cycle
    want = (st["emitted"] - snap["emitted"]) > 0
    admitted = (snap["pend_valid"] | want) & ~st["pend_valid"]
    occ = _class_sums(cls, st["outstanding"])
    adm = _class_sums(cls, admitted)
    iss = _class_sums(cls, dram["issued"] - snap["issued"])
    hits = jnp.sum(dram["hits"] - snap["hits"])
    if "marked" in snap:
        marks = jnp.sum(sched["marked"] & ~snap["marked"]).astype(jnp.int32)
    else:
        marks = jnp.int32(0)
    if "pd_down" in dram:
        pd = jnp.sum(dram["pd_down"].astype(jnp.int32))
    else:
        pd = jnp.int32(0)
    inc = jnp.concatenate([
        occ, adm, iss,
        jnp.stack([hits, marks, pd, jnp.int32(1)]),
    ]).astype(jnp.int32)
    onehot = (jnp.arange(W, dtype=jnp.int32) == jnp.mod(e, W))
    dram = dict(dram)
    dram["tl_ring"] = ring + onehot[:, None].astype(jnp.int32) * inc[None, :]
    dram["tl_epoch"] = e
    return dram


def skip_accrue(cfg: SimConfig, pool, st, dram, t, t_new) -> Dict[str, Any]:
    """Charge the jumped span t+1 .. t_new-1 in one add — exactly what the
    ticked driver's per-cycle `tick_accrue` would have recorded.

    Valid under the witness contract: no admission, issue, completion,
    emission, or batch-mark lands strictly inside a span, so the event
    channels add zero, occupancy is frozen, and the only power-down
    transition is standby -> power-down at `enter = busy_until +
    energy_pd_idle` (split in closed form, mirroring
    `energy.skip_accrue`). MUST run BEFORE `energy.skip_accrue` at the
    call site: it reads the pre-span `pd_down`, which energy's final OR
    overwrites. `steps` adds nothing — skipped cycles are not processed
    steps; that is the skip meter's definition, not an approximation.
    """
    W, E = cfg.telemetry_window, cfg.telemetry_epoch
    a, b = t + 1, t_new - 1                      # empty when t_new == t+1
    eb = b // E
    e_s = _slot_epochs(W, eb)
    lo = jnp.maximum(e_s * E, a)
    hi = jnp.minimum(e_s * E + E - 1, b)
    n_s = jnp.clip(hi - lo + 1, 0, E)            # span cycles per slot (W,)
    ring = _advance(W, dram["tl_ring"], dram["tl_epoch"], eb)
    cls = pool["src_class"]
    occ = _class_sums(cls, st["outstanding"])    # frozen during the span
    zeros = jnp.zeros((W,), jnp.int32)
    cols = [n_s * occ[c] for c in range(N_CLASSES)]         # occ_*
    cols += [zeros] * (2 * N_CLASSES + 2)        # adm_*, iss_*, hits, marks
    if "pd_down" in dram:
        # per slot x channel: cycles u in the slot's span overlap with
        # end-of-cycle pd_down, i.e. pd_pre | (u >= enter)
        enter = dram["busy_until"] + cfg.energy_pd_idle
        cnt = jnp.where(
            dram["pd_down"][None, :], n_s[:, None],
            jnp.clip(hi[:, None] - jnp.maximum(enter[None, :],
                                               lo[:, None]) + 1,
                     0, n_s[:, None]))
        cols.append(jnp.sum(cnt, axis=1).astype(jnp.int32))
    else:
        cols.append(zeros)
    cols.append(zeros)                           # steps: skip meter
    dram = dict(dram)
    dram["tl_ring"] = ring + jnp.stack(cols, axis=1)
    dram["tl_epoch"] = jnp.maximum(dram["tl_epoch"], eb)
    return dram


# ---------------------------------------------------------------------------
# host-side helpers (numpy-friendly; used by metrics.timeline_breakdown)
# ---------------------------------------------------------------------------

def ring_epochs(W: int, final_epoch):
    """Epoch index held by each ring slot at end of run (negative => the
    slot was never written and still holds zeros)."""
    import numpy as np
    s = np.arange(W)
    e = int(final_epoch)
    return e - np.mod(e - s, W)


def ordered_view(ring, final_epoch):
    """(W, K) ring -> (epochs ascending, (W, K) rows, valid mask)."""
    import numpy as np
    ring = np.asarray(ring)
    W = ring.shape[0]
    epochs = ring_epochs(W, final_epoch)
    order = np.argsort(epochs)
    return epochs[order], ring[order], epochs[order] >= 0
