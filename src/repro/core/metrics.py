"""System metrics: weighted speedup, max slowdown, harmonic speedup (§5)."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.params import SimConfig
from repro.core.workloads import CPU_BENCH, GPU_BENCH, Workload


def per_source_alone(cfg: SimConfig, wl: Workload,
                     alone: Dict[str, float]) -> np.ndarray:
    """Alone performance vector (S,) for one workload."""
    out = np.ones((cfg.n_src,), np.float64)
    for i, b in enumerate(wl.cpu_ids[:cfg.n_cpu]):
        out[i] = max(alone[CPU_BENCH[b][0]], 1e-9)
    out[cfg.n_cpu] = max(alone[GPU_BENCH[wl.gpu_id][0]], 1e-9)
    return out


def workload_metrics(cfg: SimConfig, wl: Workload, shared_perf: np.ndarray,
                     alone: Dict[str, float]) -> Dict[str, float]:
    """shared_perf: (S,) per-source perf (IPC for CPUs, BW for GPU)."""
    alone_v = per_source_alone(cfg, wl, alone)
    ratio = np.maximum(shared_perf, 1e-9) / alone_v
    n = cfg.n_cpu
    cpu_ws = float(ratio[:n].sum())
    gpu_su = float(ratio[n])
    slowdowns = 1.0 / np.maximum(ratio[:n + 1], 1e-9)
    return {
        "weighted_speedup": cpu_ws + gpu_su,
        "cpu_weighted_speedup": cpu_ws,
        "gpu_speedup": gpu_su,
        "max_slowdown": float(slowdowns.max()),
        "cpu_max_slowdown": float(slowdowns[:n].max()),
        "harmonic_speedup": float((n + 1) / (1.0 / ratio[:n + 1]).sum()),
    }


def energy_breakdown(cfg: SimConfig, m: Dict[str, np.ndarray],
                     pool_batch: Dict[str, np.ndarray], n_cycles: int,
                     static_per_cycle: float = 0.0) -> Dict[str, np.ndarray]:
    """Per-workload (W,) energy metrics from `simulate` outputs (nJ).

    m: metrics dict with the energy counters present (cfg.energy_enabled);
    static_per_cycle: scheduler-structure leakage power in nJ/cycle (see
    `power.scheduler_static_power`) folded into the full-MC totals.

    EDP here is per-request energy-delay: (energy per request) x (cycles
    per request) — runs are fixed-time, so per-request normalization is
    what makes policies comparable.
    """
    is_gpu = np.asarray(pool_batch["is_gpu"], bool)            # (W, S)
    act = np.asarray(m["energy_act"], np.float64)              # (W, S)
    rw = np.asarray(m["energy_rw"], np.float64)
    dyn = act + rw
    bg = np.asarray(m["energy_bg"], np.float64) \
        + np.asarray(m["energy_wake"], np.float64)             # (W,)
    static = float(static_per_cycle) * n_cycles
    total = dyn.sum(-1) + bg + static
    reqs = np.maximum(np.asarray(m["completed"], np.float64).sum(-1), 1.0)
    epr = total / reqs
    return {
        "energy_total": total,
        "energy_per_request": epr,
        "edp": epr * (n_cycles / reqs),
        "energy_dyn_cpu": np.where(~is_gpu, dyn, 0.0).sum(-1),
        "energy_dyn_gpu": np.where(is_gpu, dyn, 0.0).sum(-1),
        "energy_act_cpu": np.where(~is_gpu, act, 0.0).sum(-1),
        "energy_act_gpu": np.where(is_gpu, act, 0.0).sum(-1),
        # row-miss ACT share of dynamic energy: the row-hit-batching signal
        "act_energy_frac": act.sum(-1) / np.maximum(dyn.sum(-1), 1e-9),
        "background_frac": bg / np.maximum(total, 1e-9),
        "static_frac": static / np.maximum(total, 1e-9),
        "pd_frac": np.asarray(m["pd_cycles"], np.float64)
        / (cfg.n_channels * n_cycles),
    }


def aggregate(rows: Sequence[Dict[str, float]]) -> Dict[str, float]:
    keys = rows[0].keys()
    return {k: float(np.mean([r[k] for r in rows])) for k in keys}


def by_category(workloads: Sequence[Workload],
                rows: Sequence[Dict[str, float]]):
    cats: Dict[str, List[Dict[str, float]]] = {}
    for wl, r in zip(workloads, rows):
        cats.setdefault(wl.category, []).append(r)
    return {c: aggregate(rs) for c, rs in cats.items()}
