"""System metrics: weighted speedup, max slowdown, harmonic speedup (§5)."""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.params import SimConfig
from repro.core.workloads import CPU_BENCH, GPU_BENCH, Workload


def per_source_alone(cfg: SimConfig, wl: Workload,
                     alone: Dict[str, float]) -> np.ndarray:
    """Alone performance vector (S,) for one workload."""
    out = np.ones((cfg.n_src,), np.float64)
    for i, b in enumerate(wl.cpu_ids[:cfg.n_cpu]):
        out[i] = max(alone[CPU_BENCH[b][0]], 1e-9)
    out[cfg.n_cpu] = max(alone[GPU_BENCH[wl.gpu_id][0]], 1e-9)
    return out


def workload_metrics(cfg: SimConfig, wl: Workload, shared_perf: np.ndarray,
                     alone: Dict[str, float]) -> Dict[str, float]:
    """shared_perf: (S,) per-source perf (IPC for CPUs, BW for GPU)."""
    alone_v = per_source_alone(cfg, wl, alone)
    ratio = np.maximum(shared_perf, 1e-9) / alone_v
    n = cfg.n_cpu
    cpu_ws = float(ratio[:n].sum())
    gpu_su = float(ratio[n])
    slowdowns = 1.0 / np.maximum(ratio[:n + 1], 1e-9)
    return {
        "weighted_speedup": cpu_ws + gpu_su,
        "cpu_weighted_speedup": cpu_ws,
        "gpu_speedup": gpu_su,
        "max_slowdown": float(slowdowns.max()),
        "cpu_max_slowdown": float(slowdowns[:n].max()),
        "harmonic_speedup": float((n + 1) / (1.0 / ratio[:n + 1]).sum()),
    }


def aggregate(rows: Sequence[Dict[str, float]]) -> Dict[str, float]:
    keys = rows[0].keys()
    return {k: float(np.mean([r[k] for r in rows])) for k in keys}


def by_category(workloads: Sequence[Workload],
                rows: Sequence[Dict[str, float]]):
    cats: Dict[str, List[Dict[str, float]]] = {}
    for wl, r in zip(workloads, rows):
        cats.setdefault(wl.category, []).append(r)
    return {c: aggregate(rs) for c, rs in cats.items()}
