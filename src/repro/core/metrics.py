"""System metrics: weighted speedup, max slowdown, harmonic speedup (§5),
per-class QoS (deadline-met rate, tail latency, class-masked fairness)."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.params import (CLASS_NAMES, CLS_CPU, CLS_GPU, CLS_HWA,
                               SimConfig)
from repro.core.workloads import CPU_BENCH, GPU_BENCH, HWA_BENCH, Workload


def class_vector(cfg: SimConfig) -> np.ndarray:
    """Canonical (S,) class-id layout: CPUs, then GPUs, then HWAs."""
    return np.asarray([CLS_CPU] * cfg.n_cpu + [CLS_GPU] * cfg.n_gpu
                      + [CLS_HWA] * cfg.n_hwa, np.int32)


def max_slowdown(slowdowns: np.ndarray,
                 mask: Optional[np.ndarray] = None) -> float:
    """The unfairness reduction, shared by every per-class variant: max
    slowdown over the (optionally class-masked) sources. NaN when the mask
    selects nothing, so an absent class can't fake perfect fairness."""
    s = np.asarray(slowdowns, np.float64)
    if mask is not None:
        mask = np.asarray(mask, bool)
        if not mask.any():
            return float("nan")
        s = s[mask]
    return float(s.max())


def per_source_alone(cfg: SimConfig, wl: Workload,
                     alone: Dict[str, float]) -> np.ndarray:
    """Alone performance vector (S,) for one workload."""
    out = np.ones((cfg.n_src,), np.float64)
    for i, b in enumerate(wl.cpu_ids[:cfg.n_cpu]):
        out[i] = max(alone[CPU_BENCH[b][0]], 1e-9)
    out[cfg.n_cpu] = max(alone[GPU_BENCH[wl.gpu_id][0]], 1e-9)
    for j, b in enumerate(wl.hwa_ids[:cfg.n_hwa]):
        out[cfg.n_cpu + cfg.n_gpu + j] = max(alone[HWA_BENCH[b][0]], 1e-9)
    return out


def workload_metrics(cfg: SimConfig, wl: Workload, shared_perf: np.ndarray,
                     alone: Dict[str, float]) -> Dict[str, float]:
    """shared_perf: (S,) per-source perf (IPC for CPUs, BW for GPU/HWAs).

    The populated sources are the n_cpu CPUs, the GPU at index n_cpu, and
    the workload's HWAs; slowdown reductions run over exactly those, with
    the per-class variants masking the shared `max_slowdown` reduction.
    `weighted_speedup` keeps its 2-class CPU+GPU definition (the paper's
    headline metric); HWA throughput reports separately as `hwa_speedup`.
    """
    alone_v = per_source_alone(cfg, wl, alone)
    ratio = np.maximum(shared_perf, 1e-9) / alone_v
    n = cfg.n_cpu
    n_hwa = len(wl.hwa_ids[:cfg.n_hwa])
    idx = np.asarray(list(range(n)) + [n] +
                     [n + cfg.n_gpu + j for j in range(n_hwa)])
    cls = np.asarray([CLS_CPU] * n + [CLS_GPU] + [CLS_HWA] * n_hwa)
    slowdowns = 1.0 / np.maximum(ratio[idx], 1e-9)
    cpu_ws = float(ratio[:n].sum())
    gpu_su = float(ratio[n])
    out = {
        "weighted_speedup": cpu_ws + gpu_su,
        "cpu_weighted_speedup": cpu_ws,
        "gpu_speedup": gpu_su,
        "max_slowdown": max_slowdown(slowdowns),
        "cpu_max_slowdown": max_slowdown(slowdowns, cls == CLS_CPU),
        "harmonic_speedup": float(len(idx) / (1.0 / ratio[idx]).sum()),
    }
    if n_hwa:
        out["hwa_speedup"] = float(ratio[idx[cls == CLS_HWA]].sum())
        out["hwa_max_slowdown"] = max_slowdown(slowdowns, cls == CLS_HWA)
    return out


def hist_quantile(hist: np.ndarray, edges: np.ndarray, q: float
                  ) -> np.ndarray:
    """Quantile(s) from latency histograms: (..., BINS) counts -> (...,)
    upper-edge latency of the bin where the cumulative mass crosses q.
    Rows with no mass report 0."""
    h = np.asarray(hist, np.float64)
    tot = h.sum(-1)
    cum = np.cumsum(h, -1)
    idx = np.argmax(cum >= q * np.maximum(tot, 1e-9)[..., None], axis=-1)
    return np.where(tot > 0, np.asarray(edges, np.float64)[idx], 0.0)


def qos_breakdown(cfg: SimConfig, m: Dict[str, np.ndarray],
                  pool_batch: Dict[str, np.ndarray],
                  quantiles: Sequence[float] = (0.95, 0.99)
                  ) -> Dict[str, np.ndarray]:
    """Per-workload (W,) QoS metrics from `simulate` outputs.

    Per-class tail latency comes from the issue-time latency histogram
    (`lat_hist`, needs cfg.qos_enabled): source rows roll up to classes by
    masking with `src_class`, then the pooled histogram reduces to p95/p99.
    Frame-deadline accounting (HWA class): deadline-met rate over the
    frames the measurement window released.
    """
    from repro.core import qos
    cls = np.asarray(pool_batch["src_class"])                  # (W, S)
    hist = np.asarray(m["lat_hist"], np.float64)               # (W, S, B)
    edges = qos.bin_upper_edges(cfg)
    out: Dict[str, np.ndarray] = {}
    for k, kname in enumerate(CLASS_NAMES):
        pooled = np.where((cls == k)[..., None], hist, 0.0).sum(-2)
        for q in quantiles:
            out[f"lat_p{int(round(q * 100))}_{kname}"] = \
                hist_quantile(pooled, edges, q)
    hwa = cls == CLS_HWA
    rel = np.where(hwa, np.asarray(m["frames_released"], np.float64),
                   0.0).sum(-1)
    met = np.where(hwa, np.asarray(m["dl_met"], np.float64), 0.0).sum(-1)
    out["frames_released"] = rel
    out["dl_met_rate"] = met / np.maximum(rel, 1.0)
    return out


def timeline_breakdown(cfg: SimConfig, m: Dict[str, np.ndarray],
                       total_cycles: Optional[int] = None
                       ) -> Dict[str, np.ndarray]:
    """Time-resolved per-epoch series from the flight-recorder ring.

    m: metrics dict with `telemetry` (..., W, K) and `telemetry_epoch`
    (...) present (cfg.telemetry_enabled); leading axes (workload batch,
    policy stack) are flattened to one row axis. Slots are reordered into
    ascending epochs; `valid` masks slots never written (runs shorter than
    the window) and, when `total_cycles` (warmup + measured) is given, the
    per-epoch denominators account for a partial final epoch.

    Returns (R, W)-shaped series: `epoch`, `valid`, `occ_<class>` mean
    queue depth, `adm_<class>`/`iss_<class>` per-cycle rates, `lat_<class>`
    occupancy/issue-rate latency proxy (Little's law, cycles per request),
    `row_hit_rate`, `batch_marks`, `pd_frac` power-down residency, and
    `skip_ratio` (1 - processed steps / epoch cycles — the skip meter, a
    driver property, not a policy metric).
    """
    from repro.core import telemetry
    E, W = cfg.telemetry_epoch, cfg.telemetry_window
    ring = np.asarray(m["telemetry"], np.float64)
    lead = ring.shape[:-2]
    ring = ring.reshape((-1,) + ring.shape[-2:])               # (R, W, K)
    e_f = np.asarray(m["telemetry_epoch"]).reshape(-1).astype(np.int64)
    R = ring.shape[0]
    epochs = np.stack([telemetry.ring_epochs(W, e) for e in e_f])  # (R, W)
    order = np.argsort(epochs, axis=1)
    epochs = np.take_along_axis(epochs, order, axis=1)
    ring = np.take_along_axis(ring, order[:, :, None], axis=1)
    valid = epochs >= 0
    if total_cycles is not None:
        width = np.clip(total_cycles - epochs * E, 0, E).astype(np.float64)
    else:
        width = np.full((R, W), float(E))
    width = np.maximum(width, 1.0)
    ch = lambda name: ring[:, :, telemetry.CH[name]]
    out: Dict[str, np.ndarray] = {"epoch": epochs, "valid": valid}
    iss_tot = np.zeros((R, W))
    for kname in CLASS_NAMES:
        occ, adm, iss = ch(f"occ_{kname}"), ch(f"adm_{kname}"), \
            ch(f"iss_{kname}")
        iss_tot = iss_tot + iss
        out[f"occ_{kname}"] = occ / width
        out[f"adm_{kname}"] = adm / width
        out[f"iss_{kname}"] = iss / width
        # Little's law: mean in-flight / completion rate ~ mean latency
        out[f"lat_{kname}"] = occ / np.maximum(iss, 1.0)
    out["row_hit_rate"] = ch("row_hits") / np.maximum(iss_tot, 1.0)
    out["batch_marks"] = ch("batch_marks")
    out["pd_frac"] = ch("pd_chan") / (width * max(cfg.n_channels, 1))
    out["skip_ratio"] = 1.0 - ch("steps") / width
    restore = lambda a: a.reshape(lead + (W,))
    return {k: restore(v) for k, v in out.items()}


def energy_breakdown(cfg: SimConfig, m: Dict[str, np.ndarray],
                     pool_batch: Dict[str, np.ndarray], n_cycles: int,
                     static_per_cycle: float = 0.0) -> Dict[str, np.ndarray]:
    """Per-workload (W,) energy metrics from `simulate` outputs (nJ).

    m: metrics dict with the energy counters present (cfg.energy_enabled);
    static_per_cycle: scheduler-structure leakage power in nJ/cycle (see
    `power.scheduler_static_power`) folded into the full-MC totals.

    EDP here is per-request energy-delay: (energy per request) x (cycles
    per request) — runs are fixed-time, so per-request normalization is
    what makes policies comparable.
    """
    is_gpu = np.asarray(pool_batch["is_gpu"], bool)            # (W, S)
    act = np.asarray(m["energy_act"], np.float64)              # (W, S)
    rw = np.asarray(m["energy_rw"], np.float64)
    dyn = act + rw
    bg = np.asarray(m["energy_bg"], np.float64) \
        + np.asarray(m["energy_wake"], np.float64)             # (W,)
    static = float(static_per_cycle) * n_cycles
    total = dyn.sum(-1) + bg + static
    reqs = np.maximum(np.asarray(m["completed"], np.float64).sum(-1), 1.0)
    epr = total / reqs
    # the historical CPU/GPU split: everything non-GPU (including HWAs)
    # stays in the "cpu" bucket so 2-class consumers see unchanged keys;
    # 3-class runs get the per-class split from the hwa keys below
    hwa = (np.asarray(pool_batch["src_class"]) == CLS_HWA) \
        if "src_class" in pool_batch else np.zeros_like(is_gpu)
    out = {}
    if hwa.any():
        out["energy_dyn_hwa"] = np.where(hwa, dyn, 0.0).sum(-1)
        out["energy_act_hwa"] = np.where(hwa, act, 0.0).sum(-1)
    return {
        **out,
        "energy_total": total,
        "energy_per_request": epr,
        "edp": epr * (n_cycles / reqs),
        "energy_dyn_cpu": np.where(~is_gpu, dyn, 0.0).sum(-1),
        "energy_dyn_gpu": np.where(is_gpu, dyn, 0.0).sum(-1),
        "energy_act_cpu": np.where(~is_gpu, act, 0.0).sum(-1),
        "energy_act_gpu": np.where(is_gpu, act, 0.0).sum(-1),
        # row-miss ACT share of dynamic energy: the row-hit-batching signal
        "act_energy_frac": act.sum(-1) / np.maximum(dyn.sum(-1), 1e-9),
        "background_frac": bg / np.maximum(total, 1e-9),
        "static_frac": static / np.maximum(total, 1e-9),
        "pd_frac": np.asarray(m["pd_cycles"], np.float64)
        / (cfg.n_channels * n_cycles),
    }


def aggregate(rows: Sequence[Dict[str, float]]) -> Dict[str, float]:
    keys = rows[0].keys()
    return {k: float(np.mean([r[k] for r in rows])) for k in keys}


def by_category(workloads: Sequence[Workload],
                rows: Sequence[Dict[str, float]]):
    cats: Dict[str, List[Dict[str, float]]] = {}
    for wl, r in zip(workloads, rows):
        cats.setdefault(wl.category, []).append(r)
    return {c: aggregate(rs) for c, rs in cats.items()}
