"""Simulation drivers: scan over cycles, vmap over workloads, metrics.

``simulate(cfg, policy, pool_batch, active_batch, n_cycles, warmup)`` runs a
batch of workloads through one scheduler and returns per-source measured
metrics. Stats are delta-measured after a warmup period.

Policies resolve by name through `repro.core.policy.POLICY_REGISTRY`; the
drivers are generic over the `MemoryPolicy` protocol, so a newly registered
policy is immediately simulatable (and appears in `ALL_POLICIES`) with no
changes here.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import params
from repro.core import policy as policy_api
from repro.core.params import SimConfig, SourcePool

_SNAP_KEYS = ("insts_done", "emitted", "completed", "sum_lat", "dl_met",
              "dl_missed", "frames_released")
_DRAM_SNAP = ("hits", "issued")
# energy accumulators are delta-measured like the service stats; present in
# dram_state only when cfg.energy_enabled (checked against the live tree)
_ENERGY_SNAP = ("e_act", "e_rw", "sb_cycles", "e_wake", "pd_cycles")
# QoS latency histogram, present only when cfg.qos_enabled
_QOS_SNAP = ("lat_hist",)
# policy QoS counters surfaced from scheduler state when present (the
# stacked union schema gives every slice the key; zeros for policies
# without the counter)
_SCHED_SNAP = {"sq_urgent_adm": "urgent_admits"}


def __getattr__(name: str):
    # Live registry enumerations (PEP 562), in registration order, so a
    # policy registered at runtime appears immediately. POLICIES is the
    # baseline sweep (no configured variants); ALL_POLICIES adds the
    # variants, e.g. sms_dash.
    if name == "POLICIES":
        return policy_api.baseline_names()
    if name == "ALL_POLICIES":
        return policy_api.names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

def _init(cfg: SimConfig, policy: str, knobs=None):
    """Resolve the policy and build (bound cfg, policy object, carry).

    The carry holds only cycle-varying state; read-only workload parameters
    (pool, active) are closed over in `policy.make_step`. The returned cfg
    is a `params.BoundConfig`: shapes/periods stay trace-time Python values
    while value-like knobs come from `knobs` (default: cfg's own values,
    filtered through the policy's `configure_knobs`) — possibly traced
    arrays riding a vmapped variant axis.
    """
    pol = policy_api.get(policy)
    cfg = pol.configure(cfg)
    kn = policy_api.resolve_knobs(cfg, pol, knobs)
    carry = (engine.source_state(cfg), pol.init_state(cfg),
             engine.dram_state(cfg))
    return params.bind(cfg, kn), pol, carry


def _run_cycles(step, skip_body, carry, t0: int, t1: int, unroll: int):
    """Run cycles [t0, t1) — the ONE driver loop every `simulate*` variant
    routes through.

    Ticked mode (skip_body None): the chunked `lax.scan` over every cycle.
    Skipping mode: a `lax.while_loop` whose body processes one cycle and
    jumps `t` to the next-event witness (clamped to t1, so snapshot
    boundaries land exactly where the ticked driver takes them). Under
    `vmap` the while_loop batches per element — finished workloads freeze
    while stragglers run on — so the vmap/stacked structure is unchanged.

    Returns (carry, steps): steps counts processed cycles (== t1 - t0 when
    ticked, a traced scalar when skipping).
    """
    if skip_body is None:
        carry, _ = jax.lax.scan(step, carry, jnp.arange(t0, t1),
                                unroll=unroll)
        return carry, jnp.int32(t1 - t0)

    def body(state):
        carry, t, n = state
        carry, t_new = skip_body(carry, t, jnp.int32(t1))
        return carry, t_new, n + 1

    carry, _, steps = jax.lax.while_loop(
        lambda s: s[1] < t1, body, (carry, jnp.int32(t0), jnp.int32(0)))
    return carry, steps


def _scan_and_measure(cfg: SimConfig, step, skip_body, carry, n_cycles: int,
                      warmup: int, unroll: int) -> Dict[str, jax.Array]:
    """Warmup run, stat snapshot, measured run, delta metrics.

    Generic over the carry's leading axes: works for the per-policy step
    ((S,)-shaped stats) and the stacked step ((P, S)-shaped stats) alike.
    """
    carry, _ = _run_cycles(step, skip_body, carry, 0, warmup, unroll)
    st_w, sched_w, dram_w = carry
    energy_on = all(k in dram_w for k in _ENERGY_SNAP)
    qos_on = all(k in dram_w for k in _QOS_SNAP)
    snap = {k: st_w[k] for k in _SNAP_KEYS}
    snap.update({k: dram_w[k] for k in _DRAM_SNAP})
    if energy_on:
        snap.update({k: dram_w[k] for k in _ENERGY_SNAP})
    if qos_on:
        snap.update({k: dram_w[k] for k in _QOS_SNAP})
    sched_snap = {k: sched_w[k] for k in _SCHED_SNAP if k in sched_w}
    carry, steps = _run_cycles(step, skip_body, carry, warmup,
                               warmup + n_cycles, unroll)
    st_f, sched_f, dram_f = carry

    cyc = jnp.float32(n_cycles)
    d = lambda k: (st_f[k] if k in st_f else dram_f[k]).astype(jnp.float32) \
        - snap[k].astype(jnp.float32)
    completed = d("completed")
    out = {
        "ipc": d("insts_done") / cyc,
        "bw": completed / cyc,                        # requests per cycle
        "mpkc": d("emitted") / cyc * 1000.0,
        "rbl": d("hits") / jnp.maximum(d("issued"), 1.0),
        "avg_lat": d("sum_lat") / jnp.maximum(completed, 1.0),
        "completed": completed,
        "emitted": d("emitted"),
        "outstanding_end": st_f["outstanding"].astype(jnp.float32),
        "inflight_unserved": (st_f["emitted"] - st_f["completed"]
                              ).astype(jnp.float32),
        "dl_met": d("dl_met"),
        "dl_missed": d("dl_missed"),
        "frames_released": d("frames_released"),
        # processed cycles in the measured window: == n_cycles when ticked,
        # fewer when the variable-step driver skips idle spans (the skip
        # ratio is 1 - sim_steps/n_cycles). A driver property, not a
        # simulation result — broadcast over any leading policy axis.
        "sim_steps": jnp.broadcast_to(
            steps, st_f["completed"].shape[:-1]).astype(jnp.float32),
    }
    if qos_on:
        out["lat_hist"] = d("lat_hist")               # (S, BINS) counts
    if "viol" in dram_f:
        # sanitizer counters are CUMULATIVE, not delta-measured: a warmup
        # violation is still a violation. (NV,) per sim — see
        # `validate.VIOLATIONS` for the layout, `validate.summarize` to name
        out["violations"] = dram_f["viol"].astype(jnp.float32)
    if "tl_ring" in dram_f:
        # flight-recorder ring is WINDOWED, not delta-measured: the last W
        # epochs of the whole run are the measurement. (W, K) per sim plus
        # the final epoch pointer that maps slots back to epochs — see
        # `telemetry.CHANNELS` / `metrics.timeline_breakdown`.
        out["telemetry"] = dram_f["tl_ring"].astype(jnp.float32)
        out["telemetry_epoch"] = dram_f["tl_epoch"].astype(jnp.float32)
    for k, name in _SCHED_SNAP.items():
        if k in sched_snap:
            out[name] = sched_f[k].astype(jnp.float32) \
                - sched_snap[k].astype(jnp.float32)
    if energy_on:
        # per-source dynamic energy stays (S,)-shaped for the CPU/GPU class
        # breakdown; per-channel background collapses to totals. Background
        # nJ derives from the integer cycle counters at metric time (the
        # counters, not a float accumulator, are what the skipping driver
        # can charge bit-identically in one add).
        out.update({
            "energy_act": d("e_act"),                 # (S,) ACT/PRE, nJ
            "energy_rw": d("e_rw"),                   # (S,) RD/WR bursts
            "energy_bg": jnp.sum(d("sb_cycles"), -1)
            * jnp.float32(cfg.energy_standby)
            + jnp.sum(d("pd_cycles"), -1) * jnp.float32(cfg.energy_pd),
            "energy_wake": jnp.sum(d("e_wake"), -1),
            "pd_cycles": jnp.sum(d("pd_cycles"), -1),
        })
    return out


def _one_sim(cfg: SimConfig, policy: str, n_cycles: int, warmup: int,
             unroll: int, skip: bool, pool: Dict[str, jax.Array],
             active: jax.Array, knobs=None) -> Dict[str, jax.Array]:
    cfg, pol, carry = _init(cfg, policy, knobs)
    step = policy_api.make_step(cfg, pol, pool, active)
    skip_body = policy_api.make_skip_step(cfg, pol, pool, active) \
        if skip else None
    return _scan_and_measure(cfg, step, skip_body, carry, n_cycles, warmup,
                             unroll)


# Per-cycle scan unroll factor. >1 trades trace size (compile time) for
# fewer loop iterations; 1 is best for the compile-dominated sweeps.
DEFAULT_UNROLL = 1
# Variable-step driver default. skip=True jumps idle spans (bit-identical
# to ticking — pinned by tests/test_event_skip.py) but pays a per-step
# witness cost, so it is OPT-IN: a win on bursty/idle-heavy streams (the
# `workloads.bursty_batch` family skips 60-97% of cycles), a pure loss on
# saturated parity sweeps (skip ratio ~0.05). The standard benchmark
# sweeps therefore tick; pass skip=True where traffic is idle-heavy.
DEFAULT_SKIP = False


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5),
                   donate_argnums=(6, 7))
def _sim_batch(cfg: SimConfig, policy: str, n_cycles: int, warmup: int,
               unroll: int, skip: bool, pool_batch, active_batch,
               knobs=None):
    """(W, ...) metrics; with `knobs` (a `Knobs` pytree stacked on a leading
    variant axis) the whole knob grid rides an inner vmap: (W, V, ...)."""
    if knobs is None:
        return jax.vmap(lambda p, a: _one_sim(cfg, policy, n_cycles, warmup,
                                              unroll, skip, p, a)
                        )(pool_batch, active_batch)
    return jax.vmap(lambda p, a: jax.vmap(
        lambda kn: _one_sim(cfg, policy, n_cycles, warmup, unroll, skip,
                            p, a, kn))(knobs))(pool_batch, active_batch)


def _check_pool(pool: Dict[str, Any], shape) -> None:
    """Host-side pool validation: malformed columns raise a named-column
    `ValueError` at dispatch instead of silently generating garbage traffic
    (negative periods wrap the frame arithmetic, out-of-range classes fall
    through every generator, shape mismatches broadcast into wrong-source
    traffic)."""
    shape = tuple(shape)
    float_cols = ("mpki", "inst_per_miss", "rbl")
    int_cols = ("blp", "dl_period", "dl_reqs", "dl_jitter", "src_class")
    for k, v in pool.items():
        v = np.asarray(v)
        if tuple(v.shape) != shape:
            raise ValueError(
                f"pool column {k!r}: shape {tuple(v.shape)} does not match "
                f"the active shape {shape}")
        if k in float_cols and v.dtype.kind not in "fiu":
            raise ValueError(
                f"pool column {k!r}: dtype {v.dtype} is not numeric")
        if k in int_cols and v.dtype.kind not in "iu":
            raise ValueError(
                f"pool column {k!r}: dtype {v.dtype} is not integral")
        if k == "is_gpu" and v.dtype.kind != "b":
            raise ValueError(
                f"pool column 'is_gpu': dtype {v.dtype} is not bool")
    for k in ("dl_period", "dl_reqs", "dl_jitter"):
        if k in pool and np.any(np.asarray(pool[k]) < 0):
            raise ValueError(
                f"pool column {k!r}: negative values (deadline streams "
                f"use 0 for 'no deadline', never negatives)")
    if "src_class" in pool:
        sc = np.asarray(pool["src_class"])
        if np.any((sc < 0) | (sc >= params.N_CLASSES)):
            raise ValueError(
                f"pool column 'src_class': values outside the CLASS_NAMES "
                f"range [0, {params.N_CLASSES}) "
                f"(known classes: {params.CLASS_NAMES})")


def prepare_pool(pool: Dict[str, Any], shape, copy: bool = False
                 ) -> Dict[str, Any]:
    """The one pool-preparation path shared by every driver.

    Validates the columns (named-column `ValueError` on malformed input),
    moves the pool to device (fresh buffers when `copy`, for donation
    safety) and completes the N-class schema: absent deadline-stream keys
    are zero-filled, and absent `src_class` is derived from the legacy
    `is_gpu`/`dl_period` partition — so 2-class pools run bit-identically
    through the N-class engine.
    """
    _check_pool(pool, shape)
    pool = {k: jnp.array(v, copy=True) if copy else jnp.asarray(v)
            for k, v in pool.items()}
    for k in ("dl_period", "dl_reqs", "dl_jitter"):
        if k not in pool:
            pool[k] = jnp.zeros(shape, jnp.int32)
    if "src_class" not in pool:
        pool["src_class"] = engine.derive_src_class(pool["is_gpu"],
                                                    pool["dl_period"])
    return pool


def simulate_async(cfg: SimConfig, policy: str,
                   pool_batch: Dict[str, np.ndarray],
                   active_batch: np.ndarray, n_cycles: int = 20_000,
                   warmup: int = 2_000, unroll: int = None,
                   skip: bool = None) -> Dict[str, jax.Array]:
    """Dispatch a batch sim and return DEVICE arrays without blocking.

    JAX's async dispatch means the scan executes in the background; callers
    (the benchmark sweeps) issue every policy's sim first and only then
    convert to numpy, overlapping device compute with host post-processing.
    Inputs are copied into fresh device buffers per call (`copy=True` — so
    the donation to the jitted computation can never invalidate a caller's
    live jax array).
    """
    pool_batch = prepare_pool(pool_batch, np.asarray(active_batch).shape,
                              copy=True)
    with warnings.catch_warnings():
        # donation is shape-matched: the f32 pool columns alias into the
        # f32 metric outputs, the small int/bool ones can't — fine
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _sim_batch(cfg, policy, n_cycles, warmup,
                          DEFAULT_UNROLL if unroll is None else unroll,
                          DEFAULT_SKIP if skip is None else skip,
                          pool_batch, jnp.array(active_batch, copy=True))


def simulate(cfg: SimConfig, policy: str, pool_batch: Dict[str, np.ndarray],
             active_batch: np.ndarray, n_cycles: int = 20_000,
             warmup: int = 2_000, unroll: int = None,
             skip: bool = None) -> Dict[str, np.ndarray]:
    """pool_batch: dict of (W, S) arrays; active_batch: (W, S) bool."""
    out = simulate_async(cfg, policy, pool_batch, active_batch, n_cycles,
                         warmup, unroll, skip)
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# knob-grid execution: a (variant, workload) sweep of ONE policy in ONE
# compiled program (ROADMAP "Tunable knobs contract"). Value-like knob
# points stack on a vmapped variant axis inside `_sim_batch`.
# ---------------------------------------------------------------------------

def _knob_points(cfg: SimConfig, points) -> params.Knobs:
    """Normalize a sequence of knob points (override dicts or `Knobs`) to a
    variant-stacked Knobs pytree."""
    kns = [params.Knobs.from_cfg(cfg, **pt) if isinstance(pt, dict) else pt
           for pt in points]
    return params.stack_knobs(kns)


def simulate_grid_async(cfg: SimConfig, policy: str, points,
                        pool_batch: Dict[str, np.ndarray],
                        active_batch: np.ndarray, n_cycles: int = 20_000,
                        warmup: int = 2_000, unroll: int = None,
                        skip: bool = None) -> Dict[str, jax.Array]:
    """One dispatch for a knob grid of one policy; (W, V, ...) device arrays.

    `points` is a sequence of value-knob override dicts (or `Knobs`); the
    grid shares a single scan body and jits into one XLA program, vmapped
    over (workload, variant). Period-like knobs are rejected here — they
    need per-slice traces (see `simulate_stacked_grid`).
    """
    pool_batch = prepare_pool(pool_batch, np.asarray(active_batch).shape,
                              copy=True)
    knobs = _knob_points(cfg, points)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _sim_batch(cfg, policy, n_cycles, warmup,
                          DEFAULT_UNROLL if unroll is None else unroll,
                          DEFAULT_SKIP if skip is None else skip,
                          pool_batch, jnp.array(active_batch, copy=True),
                          knobs)


def simulate_grid(cfg: SimConfig, policy: str, points,
                  pool_batch: Dict[str, np.ndarray],
                  active_batch: np.ndarray, n_cycles: int = 20_000,
                  warmup: int = 2_000, unroll: int = None,
                  skip: bool = None) -> list:
    """Per-variant (W, S) metric dicts, parallel to `points`.

    Each variant slice is bit-identical to a `simulate` run with the same
    values baked into SimConfig (pinned by tests/test_knobs.py)."""
    out = simulate_grid_async(cfg, policy, points, pool_batch, active_batch,
                              n_cycles, warmup, unroll, skip)
    host = {k: np.asarray(v) for k, v in out.items()}
    n = len(points)
    return [{k: v[:, i] for k, v in host.items()} for i in range(n)]


# ---------------------------------------------------------------------------
# stacked cross-policy execution: the whole stackable CentralizedPolicy
# family in ONE scan / ONE XLA program (see schedulers.make_stacked_step)
# ---------------------------------------------------------------------------

def stackable_names(cfg: SimConfig, policies=None) -> Tuple[str, ...]:
    """The subset of `policies` (default: full registry) that opts into the
    stacked execution path under this config."""
    names = policy_api.names() if policies is None else policies
    return tuple(n for n in names if policy_api.is_stackable(n, cfg))


def _init_stacked(cfg: SimConfig, policies: Tuple[str, ...]):
    """Resolve + validate the family and build the stacked (P, ...) carry."""
    from repro.core import schedulers

    pols = [policy_api.get(p) for p in policies]
    bad = [p for p in policies if not policy_api.is_stackable(p, cfg)]
    if bad:
        raise ValueError(f"not stackable under this config: {bad}")
    bufs = schedulers.stacked_union_state(cfg, pols)
    stack = schedulers._stack_trees
    P = len(pols)
    carry = (stack([engine.source_state(cfg)] * P), stack(bufs),
             stack([engine.dram_state(cfg)] * P))
    return pols, carry


def _one_sim_stacked(cfg: SimConfig, policies: Tuple[str, ...], n_cycles: int,
                     warmup: int, unroll: int, skip: bool,
                     pool: Dict[str, jax.Array], active: jax.Array
                     ) -> Dict[str, jax.Array]:
    from repro.core import schedulers

    pols, carry = _init_stacked(cfg, policies)
    step = schedulers.make_stacked_step(cfg, pols, pool, active)
    skip_body = schedulers.make_stacked_skip_step(cfg, pols, pool, active) \
        if skip else None
    return _scan_and_measure(cfg, step, skip_body, carry, n_cycles, warmup,
                             unroll)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5),
                   donate_argnums=(6, 7))
def _sim_batch_stacked(cfg: SimConfig, policies: Tuple[str, ...],
                       n_cycles: int, warmup: int, unroll: int, skip: bool,
                       pool_batch, active_batch):
    return jax.vmap(lambda p, a: _one_sim_stacked(cfg, policies, n_cycles,
                                                  warmup, unroll, skip, p, a)
                    )(pool_batch, active_batch)


def simulate_stacked_async(cfg: SimConfig, policies,
                           pool_batch: Dict[str, np.ndarray],
                           active_batch: np.ndarray, n_cycles: int = 20_000,
                           warmup: int = 2_000, unroll: int = None,
                           skip: bool = None) -> Dict[str, jax.Array]:
    """One dispatch for the whole stacked family; (W, P, S) device arrays.

    The per-policy trace+compile is amortized: the family shares a single
    scan body and jits into one XLA program, vmapped over (policy, workload).
    Same async-dispatch / buffer-copy contract as `simulate_async`.
    """
    pool_batch = prepare_pool(pool_batch, np.asarray(active_batch).shape,
                              copy=True)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _sim_batch_stacked(cfg, tuple(policies), n_cycles, warmup,
                                  DEFAULT_UNROLL if unroll is None else unroll,
                                  DEFAULT_SKIP if skip is None else skip,
                                  pool_batch, jnp.array(active_batch,
                                                        copy=True))


def simulate_stacked(cfg: SimConfig, policies,
                     pool_batch: Dict[str, np.ndarray],
                     active_batch: np.ndarray, n_cycles: int = 20_000,
                     warmup: int = 2_000, unroll: int = None,
                     skip: bool = None) -> Dict[str, Dict[str, np.ndarray]]:
    """Per-policy (W, S) metrics for a stacked family, keyed by name.

    Results are bit-identical to per-policy `simulate` calls (pinned by
    tests/test_stacked_vmap.py against the golden digests); `sim_steps` is
    the exception — the stacked slices share one variable-step loop, so
    they report the family's common step count, not the per-policy one.
    """
    out = simulate_stacked_async(cfg, policies, pool_batch, active_batch,
                                 n_cycles, warmup, unroll, skip)
    host = {k: np.asarray(v) for k, v in out.items()}
    return {pol: {k: v[:, i] for k, v in host.items()}
            for i, pol in enumerate(policies)}


# ---------------------------------------------------------------------------
# stacked (policy x knob-variant) grid: the DSE driver. Slices stack policy
# AND knob variants on the same leading axis; value-like knobs ride the
# stacked Knobs pytree through the shared engine work while period-like
# overrides re-trace only that slice's hooks (trace-time dispatch, so every
# boundary cond and skip witness survives).
# ---------------------------------------------------------------------------

def _norm_grid_slices(cfg: SimConfig, slices):
    """Split mixed per-slice overrides into the static (hashable) slice
    spec — (policy, sorted period-knob items) — and the value-knob points.
    """
    static, points = [], []
    for s in slices:
        name, ov = (s, {}) if isinstance(s, str) else s
        per, val = params.split_overrides(dict(ov))
        static.append((name, tuple(sorted(per.items()))))
        points.append(params.Knobs.from_cfg(cfg, **val))
    return tuple(static), points


def _init_stacked_grid(cfg: SimConfig, slices):
    """Resolve + validate grid slices; (pols, per-slice cfgs, carry)."""
    from repro.core import schedulers

    pols = [policy_api.get(name) for name, _ in slices]
    cfgs = [cfg.replace(**dict(ov)) for _, ov in slices]
    bad = [name for (name, _), c in zip(slices, cfgs)
           if not policy_api.is_stackable(name, c)]
    if bad:
        raise ValueError(f"not stackable under this config: {bad}")
    # period overrides never touch array shapes, so the union schema and the
    # engine state stack exactly as in `_init_stacked`
    bufs = schedulers.stacked_union_state(cfg, pols)
    stack = schedulers._stack_trees
    P = len(pols)
    carry = (stack([engine.source_state(cfg)] * P), stack(bufs),
             stack([engine.dram_state(cfg)] * P))
    return pols, cfgs, carry


def _one_sim_stacked_grid(cfg: SimConfig, slices, n_cycles: int, warmup: int,
                          unroll: int, skip: bool, pool, active, knobs):
    from repro.core import schedulers

    pols, cfgs, carry = _init_stacked_grid(cfg, slices)
    bcfgs = [params.bind(c, policy_api.resolve_knobs(
        c, p, schedulers._slice_tree(knobs, i)))
        for i, (p, c) in enumerate(zip(pols, cfgs))]
    step = schedulers.make_stacked_step(cfg, pols, pool, active,
                                        cfgs=bcfgs, knobs=knobs)
    skip_body = schedulers.make_stacked_skip_step(
        cfg, pols, pool, active, cfgs=bcfgs, knobs=knobs) if skip else None
    return _scan_and_measure(cfg, step, skip_body, carry, n_cycles, warmup,
                             unroll)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5),
                   donate_argnums=(6, 7))
def _sim_batch_stacked_grid(cfg: SimConfig, slices, n_cycles: int,
                            warmup: int, unroll: int, skip: bool,
                            pool_batch, active_batch, knobs):
    return jax.vmap(lambda p, a: _one_sim_stacked_grid(
        cfg, slices, n_cycles, warmup, unroll, skip, p, a, knobs)
        )(pool_batch, active_batch)


def simulate_stacked_grid_async(cfg: SimConfig, slices,
                                pool_batch: Dict[str, np.ndarray],
                                active_batch: np.ndarray,
                                n_cycles: int = 20_000, warmup: int = 2_000,
                                unroll: int = None, skip: bool = None
                                ) -> Dict[str, jax.Array]:
    """One dispatch for a (policy x knob-variant) grid; (W, N, S) arrays.

    `slices` is a sequence of policy names or (policy, overrides) pairs;
    overrides may mix value-like knobs (batched on the variant axis) and
    period-like knobs (per-slice trace-time dispatch). Policies may repeat
    — e.g. 6 policies x 4 knob points = 24 slices in ONE XLA program.
    """
    static, points = _norm_grid_slices(cfg, slices)
    knobs = params.stack_knobs(points)
    pool_batch = prepare_pool(pool_batch, np.asarray(active_batch).shape,
                              copy=True)
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _sim_batch_stacked_grid(
            cfg, static, n_cycles, warmup,
            DEFAULT_UNROLL if unroll is None else unroll,
            DEFAULT_SKIP if skip is None else skip,
            pool_batch, jnp.array(active_batch, copy=True), knobs)


def simulate_stacked_grid(cfg: SimConfig, slices,
                          pool_batch: Dict[str, np.ndarray],
                          active_batch: np.ndarray, n_cycles: int = 20_000,
                          warmup: int = 2_000, unroll: int = None,
                          skip: bool = None) -> list:
    """Per-slice (W, S) metric dicts, parallel to `slices`.

    Each slice is bit-identical to a solo `simulate` run with the same
    overrides baked into SimConfig (tests/test_knobs.py), with the usual
    stacked-path exception for the shared `sim_steps` step meter."""
    out = simulate_stacked_grid_async(cfg, slices, pool_batch, active_batch,
                                      n_cycles, warmup, unroll, skip)
    host = {k: np.asarray(v) for k, v in out.items()}
    return [{k: v[:, i] for k, v in host.items()}
            for i in range(len(slices))]


def simulate_debug_stacked(cfg: SimConfig, policies,
                           pool: Dict[str, np.ndarray], active: np.ndarray,
                           n_cycles: int = 2_000, skip: bool = None):
    """Stacked-path analog of `simulate_debug` (no workload vmap).

    Returns {policy: (src_state, sched_state, dram_state)} numpy trees —
    each policy's slice of the final stacked raw state, with the scheduler
    state restricted to that policy's own (unpadded) keys.
    """
    from repro.core import schedulers

    policies = tuple(policies)
    pool = prepare_pool(pool, (cfg.n_src,))
    pols, carry = _init_stacked(cfg, policies)
    active = jnp.asarray(active)
    step = schedulers.make_stacked_step(cfg, pols, pool, active)
    skip_body = schedulers.make_stacked_skip_step(cfg, pols, pool, active) \
        if (DEFAULT_SKIP if skip is None else skip) else None

    @jax.jit
    def run(carry):
        return _run_cycles(step, skip_body, carry, 0, n_cycles,
                           DEFAULT_UNROLL)[0]

    st_f, sched_f, dram_f = run(carry)
    own = [set(p.init_state(cfg)) for p in pols]
    take = lambda tree, i, keys=None: {
        k: np.asarray(v[i]) for k, v in tree.items()
        if keys is None or k in keys}
    return {pol: (take(st_f, i), take(sched_f, i, own[i]), take(dram_f, i))
            for i, pol in enumerate(policies)}


def simulate_debug(cfg: SimConfig, policy: str, pool: Dict[str, np.ndarray],
                   active: np.ndarray, n_cycles: int = 2_000,
                   skip: bool = None):
    """Single-workload run returning the FINAL RAW STATE (invariant tests).

    pool: dict of (S,) arrays; active: (S,) bool.
    Returns (src_state, sched_state, dram_state) as numpy trees.
    """
    pool = prepare_pool(pool, (cfg.n_src,))
    cfg, pol, carry = _init(cfg, policy)
    active = jnp.asarray(active)
    step = policy_api.make_step(cfg, pol, pool, active)
    skip_body = policy_api.make_skip_step(cfg, pol, pool, active) \
        if (DEFAULT_SKIP if skip is None else skip) else None

    @jax.jit
    def run(carry):
        return _run_cycles(step, skip_body, carry, 0, n_cycles,
                           DEFAULT_UNROLL)[0]

    st_f, sched_f, dram_f = run(carry)
    to_np = lambda t: jax.tree_util.tree_map(np.asarray, t)
    return to_np(st_f), to_np(sched_f), to_np(dram_f)


def perf_vector(cfg: SimConfig, metrics: Dict[str, np.ndarray],
                pool_batch: Dict[str, np.ndarray]) -> np.ndarray:
    """Per-source performance, (W, S): IPC for CPU-class sources, attained
    BW for the streaming classes (GPU, HWA)."""
    if "src_class" in pool_batch:
        cls = np.asarray(pool_batch["src_class"])
    else:
        dlp = np.asarray(pool_batch.get(
            "dl_period", np.zeros_like(pool_batch["is_gpu"], np.int32)))
        cls = np.asarray(engine.derive_src_class(
            np.asarray(pool_batch["is_gpu"], bool), dlp))
    return np.where(cls == params.CLS_CPU, metrics["ipc"], metrics["bw"])
