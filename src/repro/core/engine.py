"""Shared simulator machinery: source/core models, DRAM state, completion.

Everything is expressed as fixed-shape masked array ops so the per-cycle step
jits into one `lax.scan` body and `vmap`s over workloads.

Shapes (per workload): S = n_src sources, C = channels, B = banks/channel.
Completion ring: RING > max access latency, indexed by absolute cycle % RING.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, qos, telemetry, validate
from repro.core.params import (CLS_CPU, CLS_GPU, CLS_HWA, SimConfig,
                               SourcePool)

RING = 64
NEG_T = -100_000
# "no event" sentinel for the variable-step driver's next-event witnesses:
# far beyond any simulated horizon, small enough that int32 arithmetic on
# witness candidates can never wrap
INF_T = 1 << 30

# source_state keys added by the N-class requester model (golden digests
# predate them; the digest tests whitelist exactly this tuple)
NCLASS_SRC_KEYS = ("frames_released",)


@functools.lru_cache(maxsize=None)
def addr_base(n_src: int, n_channels: int, n_banks: int) -> np.ndarray:
    """Loop-invariant address-gen stripe origins, hoisted out of the
    per-cycle step (embedded as a literal constant in the trace)."""
    return (np.arange(n_src, dtype=np.int32) * 3) % (n_channels * n_banks)


# ---------------------------------------------------------------------------
# one-hot masked writes — the hot-loop replacement for scatter ops.
# XLA:CPU lowers gather/scatter inside a scan body to serial per-element
# loops; a compare-mask + select over the same (C, N) array fuses into the
# surrounding elementwise work and is ~10x faster. All per-cycle state
# updates with traced indices go through these.
# ---------------------------------------------------------------------------

def masked_set(a: jax.Array, idx: jax.Array, v, do: jax.Array) -> jax.Array:
    """a[c, idx[c]] = v[c] where do[c]; a: (C, N), idx/do: (C,)."""
    mask = (jnp.arange(a.shape[-1]) == idx[:, None]) & do[:, None]
    if jnp.ndim(v) == 1:
        v = v[:, None]
    return jnp.where(mask, v, a)


def masked_set2(a: jax.Array, idx1: jax.Array, idx2: jax.Array, v,
                do: jax.Array) -> jax.Array:
    """a[c, idx1[c], idx2[c]] = v[c] where do[c]; a: (C, M, N)."""
    mask = (jnp.arange(a.shape[-2])[:, None] == idx1[:, None, None]) & \
        (jnp.arange(a.shape[-1]) == idx2[:, None, None]) & \
        do[:, None, None]
    if jnp.ndim(v) == 1:
        v = v[:, None, None]
    return jnp.where(mask, v, a)


def masked_add(a: jax.Array, idx: jax.Array, v, do: jax.Array) -> jax.Array:
    """a[c, idx[c]] += v[c] where do[c]; a: (C, N), idx/do: (C,)."""
    mask = (jnp.arange(a.shape[-1]) == idx[:, None]) & do[:, None]
    if jnp.ndim(v) == 1:
        v = v[:, None]
    return a + mask.astype(a.dtype) * v


def accum_by_index(acc: jax.Array, idx: jax.Array, v, do: jax.Array
                   ) -> jax.Array:
    """acc[idx[c]] += v[c] where do[c]; acc: (N,), idx/do: (C,).

    Duplicate indices across channels accumulate, matching scatter-add.
    """
    onehot = (jnp.arange(acc.shape[0]) == idx[:, None]) & do[:, None]
    if jnp.ndim(v) == 1:
        v = v[:, None]
    return acc + jnp.sum(onehot.astype(acc.dtype) * v, axis=0)


# ---------------------------------------------------------------------------
# cheap counter RNG (threefry is too heavy inside a per-cycle scan)
# ---------------------------------------------------------------------------

def lcg_step(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: uint32 state. Returns (new_state, u01 float32)."""
    x = x * jnp.uint32(1664525) + jnp.uint32(1013904223)
    u = (x >> jnp.uint32(8)).astype(jnp.float32) / jnp.float32(1 << 24)
    return x, u


def lcg_skip(x: jax.Array, k: jax.Array) -> jax.Array:
    """Advance the LCG state by a traced number of steps in O(log k).

    The per-step map f(x) = A·x + C is affine, so f^k is the affine map
    obtained by binary exponentiation over k's bits — the closed form the
    variable-step driver uses to keep skipped spans bit-identical to
    ticking (each skipped cycle consumes its rng draws without observing
    them). k: scalar int (>= 0; k = 0 is the identity). uint32 wrap-around
    arithmetic throughout, exactly matching repeated `lcg_step`.
    """
    A, C = jnp.uint32(1664525), jnp.uint32(1013904223)
    kk = k.astype(jnp.uint32)
    acc_a, acc_c = jnp.uint32(1), jnp.uint32(0)
    pow_a, pow_c = A, C
    for i in range(32):                     # static: k fits in 32 bits
        take = ((kk >> jnp.uint32(i)) & jnp.uint32(1)) == jnp.uint32(1)
        acc_a, acc_c = (jnp.where(take, pow_a * acc_a, acc_a),
                        jnp.where(take, pow_a * acc_c + pow_c, acc_c))
        pow_a, pow_c = pow_a * pow_a, pow_a * pow_c + pow_c
    return acc_a * x + acc_c


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------

def source_state(cfg: SimConfig) -> Dict[str, Any]:
    S = cfg.n_src
    z_i = jnp.zeros((S,), jnp.int32)
    z_f = jnp.zeros((S,), jnp.float32)
    return {
        "insts_acc": z_f, "insts_done": z_f,
        "outstanding": z_i, "emitted": z_i, "completed": z_i,
        "sum_lat": z_f,
        "pend_valid": jnp.zeros((S,), bool),
        "pend_bank": z_i, "pend_row": z_i, "pend_birth": z_i,
        "cur_bank": z_i, "cur_row": z_i, "bank_ptr": z_i,
        "rng": (jnp.arange(S, dtype=jnp.uint32) * jnp.uint32(2654435761)
                + jnp.uint32(12345)),
        # measurement helpers (Fig 1): bank occupancy snapshots
        "blp_sum": z_f, "blp_n": z_f,
        # frame-deadline accounting (HWA class / SMS-DASH)
        "period_done": z_i, "dl_met": z_i, "dl_missed": z_i,
        "frames_released": z_i,
    }


def dram_state(cfg: SimConfig) -> Dict[str, Any]:
    C, B = cfg.n_channels, cfg.n_banks
    return {
        "bank_free": jnp.zeros((C, B), jnp.int32),
        "open_row": jnp.full((C, B), -1, jnp.int32),
        "open_valid": jnp.zeros((C, B), bool),
        "act_ring": jnp.full((C, 4), NEG_T, jnp.int32),
        "bus_free": jnp.zeros((C,), jnp.int32),
        "ring": jnp.zeros((RING, cfg.n_src), jnp.int32),
        # measured service stats
        "hits": jnp.zeros((cfg.n_src,), jnp.int32),
        "issued": jnp.zeros((cfg.n_src,), jnp.int32),
        # energy counters (empty dict when cfg.energy_enabled is off)
        **energy.energy_state(cfg),
        # QoS latency histogram (empty dict when cfg.qos_enabled is off)
        **qos.qos_state(cfg),
        # invariant-sanitizer counters (empty when cfg.validate_enabled off)
        **validate.validate_state(cfg),
        # flight-recorder ring (empty when cfg.telemetry_enabled off)
        **telemetry.telemetry_state(cfg),
    }


def derive_src_class(is_gpu: jax.Array, dl_period: jax.Array) -> jax.Array:
    """Class ids for legacy pools that predate `src_class`: the GPU flag
    wins, a deadline stream marks an HWA, everything else is a CPU core.
    This reproduces the old `is_gpu` / `dl_period > 0` partition exactly,
    so derived classes keep 2-class pools bit-identical."""
    return jnp.where(jnp.asarray(is_gpu, bool), CLS_GPU,
                     jnp.where(jnp.asarray(dl_period) > 0, CLS_HWA,
                               CLS_CPU)).astype(jnp.int32)


def pool_arrays(pool: SourcePool) -> Dict[str, jax.Array]:
    S = len(pool.mpki)
    dlp = pool.dl_period if pool.dl_period is not None else np.zeros(S)
    dlr = pool.dl_reqs if pool.dl_reqs is not None else np.zeros(S)
    dlj = pool.dl_jitter if pool.dl_jitter is not None else np.zeros(S)
    out = {
        "mpki": jnp.asarray(pool.mpki, jnp.float32),
        "inst_per_miss": jnp.asarray(pool.inst_per_miss(), jnp.float32),
        "rbl": jnp.asarray(pool.rbl, jnp.float32),
        "blp": jnp.asarray(pool.blp, jnp.int32),
        "is_gpu": jnp.asarray(pool.is_gpu, bool),
        "dl_period": jnp.asarray(dlp, jnp.int32),
        "dl_reqs": jnp.asarray(dlr, jnp.int32),
        "dl_jitter": jnp.asarray(dlj, jnp.int32),
    }
    out["src_class"] = (jnp.asarray(pool.src_class, jnp.int32)
                        if pool.src_class is not None else
                        derive_src_class(out["is_gpu"], out["dl_period"]))
    return out


# ---------------------------------------------------------------------------
# per-cycle: core progress + request generation into the pending register
# ---------------------------------------------------------------------------

def frame_release_offset(S: int, frame: jax.Array, dl_jitter: jax.Array
                         ) -> jax.Array:
    """Per-(source, frame) release jitter in [0, dl_jitter] cycles.

    Stateless integer hash of the source id and frame index (LCG-style
    mixing), NOT a draw from the source `rng` stream — consuming that
    stream would shift every downstream address draw and break the
    2-class bit-identity contract. Zero jitter hashes to offset 0.
    """
    mix = (jnp.arange(S, dtype=jnp.uint32) * jnp.uint32(2654435761)) ^ \
        (frame.astype(jnp.uint32) * jnp.uint32(2246822519))
    h = mix * jnp.uint32(1664525) + jnp.uint32(1013904223)
    span = jnp.asarray(dl_jitter).astype(jnp.uint32) + jnp.uint32(1)
    return ((h >> jnp.uint32(8)) % span).astype(jnp.int32)


def source_tick(cfg: SimConfig, pool: Dict[str, jax.Array],
                st: Dict[str, Any], active: jax.Array, t: jax.Array
                ) -> Dict[str, Any]:
    """Advance cores one cycle; fill empty pending registers.

    active: (S,) bool — which sources exist in this workload (masking lets a
    single jitted sim serve every workload mix and the alone-runs).

    The traffic generator is picked by `pool["src_class"]`: CPU cores are
    MLP-limit cores (instruction progress between misses), the GPU is an
    always-wanting streaming generator, HWAs emit periodic frame bursts —
    each frame releases up to `dl_reqs` requests after a per-frame jitter
    offset, due at the next `dl_period` boundary (`deadline_tick`).
    """
    S = cfg.n_src
    cls = pool["src_class"]
    is_gpu = cls == CLS_GPU
    is_hwa = cls == CLS_HWA
    is_cpu = cls == CLS_CPU
    # GPU/HWA are DMA-like streaming engines: deep request queues
    mshr = jnp.where(is_gpu, cfg.gpu_mshr,
                     jnp.where(is_hwa, cfg.hwa_mshr, cfg.cpu_mshr))
    room = st["outstanding"] < mshr
    # CPU: progress instructions while not blocked on a full window and not
    # waiting for MC admission
    can_run = active & is_cpu & room & ~st["pend_valid"]
    st = dict(st)
    st["insts_acc"] = st["insts_acc"] + jnp.where(can_run, cfg.cpu_ipc, 0.0)
    st["insts_done"] = st["insts_done"] + jnp.where(can_run, cfg.cpu_ipc, 0.0)

    want_cpu = active & is_cpu & (st["insts_acc"] >= pool["inst_per_miss"]) \
        & ~st["pend_valid"] & room
    want_gpu = active & is_gpu & ~st["pend_valid"] & room
    # HWA: emit only this frame's remaining demand, once the frame's
    # jittered release point has passed (offset 0 when dl_jitter is 0,
    # which keeps legacy deadline sources bit-identical)
    period = jnp.maximum(pool["dl_period"], 1)
    released = jnp.mod(t, period) >= \
        frame_release_offset(S, t // period, pool["dl_jitter"])
    want_accel = active & is_hwa & ~st["pend_valid"] & room & released & \
        (st["period_done"] + st["outstanding"] < pool["dl_reqs"])
    want = want_cpu | want_gpu | want_accel

    # address generation (one LCG draw per source per cycle; cheap)
    rng, u = lcg_step(st["rng"])
    rng2, u2 = lcg_step(rng)
    st["rng"] = rng2
    same = u < pool["rbl"]
    n_banks_total = cfg.n_channels * cfg.n_banks
    base = jnp.asarray(addr_base(S, cfg.n_channels, cfg.n_banks))
    new_ptr = st["bank_ptr"] + 1
    new_bank = (base + new_ptr % jnp.maximum(pool["blp"], 1)) % n_banks_total
    new_row = (u2 * cfg.n_rows).astype(jnp.int32)
    bank = jnp.where(same, st["cur_bank"], new_bank)
    row = jnp.where(same, st["cur_row"], new_row)

    st["cur_bank"] = jnp.where(want, bank, st["cur_bank"])
    st["cur_row"] = jnp.where(want, row, st["cur_row"])
    st["bank_ptr"] = jnp.where(want & ~same, new_ptr, st["bank_ptr"])
    st["pend_bank"] = jnp.where(want, bank, st["pend_bank"])
    st["pend_row"] = jnp.where(want, row, st["pend_row"])
    st["pend_birth"] = jnp.where(want, t, st["pend_birth"])
    st["pend_valid"] = st["pend_valid"] | want
    st["insts_acc"] = jnp.where(want_cpu, st["insts_acc"] -
                                pool["inst_per_miss"], st["insts_acc"])
    st["emitted"] = st["emitted"] + want.astype(jnp.int32)
    st["outstanding"] = st["outstanding"] + want.astype(jnp.int32)
    return st


def completions_tick(st: Dict[str, Any], dram: Dict[str, Any], t: jax.Array
                     ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Return requests whose data completed this cycle to their sources."""
    slot = jnp.mod(t, RING)
    done = dram["ring"][slot]                       # (S,)
    st = dict(st)
    dram = dict(dram)
    st["outstanding"] = st["outstanding"] - done
    st["completed"] = st["completed"] + done
    st["period_done"] = st["period_done"] + done
    dram["ring"] = dram["ring"].at[slot].set(0)     # scalar-index slice
    return st, dram


def deadline_tick(cfg: SimConfig, pool: Dict[str, jax.Array],
                  st: Dict[str, Any], t: jax.Array) -> Dict[str, Any]:
    """Frame-boundary accounting for deadline (HWA/DASH) sources.

    Every elapsed frame is settled at its boundary as met or missed, so
    `frames_released == dl_met + dl_missed` holds at any boundary-aligned
    observation point (pinned by tests/test_nclass.py).
    """
    has_dl = pool["dl_period"] > 0
    boundary = has_dl & (t > 0) & \
        (jnp.mod(t, jnp.maximum(pool["dl_period"], 1)) == 0)
    met = boundary & (st["period_done"] >= pool["dl_reqs"])
    st = dict(st)
    st["frames_released"] = st["frames_released"] + boundary.astype(jnp.int32)
    st["dl_met"] = st["dl_met"] + met.astype(jnp.int32)
    st["dl_missed"] = st["dl_missed"] + (boundary & ~met).astype(jnp.int32)
    st["period_done"] = jnp.where(boundary, 0, st["period_done"])
    return st


# ---------------------------------------------------------------------------
# variable-step driver witnesses (ROADMAP "Variable-step driver contract").
#
# Each witness returns the earliest cycle > t at which the corresponding
# per-cycle hook could do anything beyond the closed-form accruals that
# `skip_sources`/`energy.skip_accrue` replay. Witnesses are evaluated on
# POST-cycle-t state and may be conservative-early (returning a cycle at
# which nothing happens is always safe — processing it is ticked-identical);
# they must never be late. INF_T means "no event from this component".
# ---------------------------------------------------------------------------

def next_source_event(cfg: SimConfig, pool: Dict[str, jax.Array],
                      st: Dict[str, Any], active: jax.Array, t: jax.Array
                      ) -> jax.Array:
    """Earliest cycle > t at which `source_tick` could emit a request or
    `deadline_tick` could settle a frame boundary, assuming no completion
    or issue lands first (those are covered by separate witnesses — any of
    them firing ends the span before this witness is trusted past it)."""
    S = cfg.n_src
    cls = pool["src_class"]
    is_gpu = cls == CLS_GPU
    is_hwa = cls == CLS_HWA
    is_cpu = cls == CLS_CPU
    mshr = jnp.where(is_gpu, cfg.gpu_mshr,
                     jnp.where(is_hwa, cfg.hwa_mshr, cfg.cpu_mshr))
    free = active & ~st["pend_valid"] & (st["outstanding"] < mshr)
    INF = jnp.int32(INF_T)
    t1 = t + 1
    # GPU: wants every cycle while its pending register is free
    w_gpu = jnp.where(jnp.any(free & is_gpu), t1, INF)
    # CPU: next inter-miss crossing. `source_tick` adds ipc then compares,
    # so the crossing cycle is t + ceil((ipm - acc)/ipc); floor(..) is the
    # conservative-early form (never late: floor <= ceil, and f32 rounding
    # on these integer-grid values is well under one whole step). Batch
    # accrual of k*ipc is bit-exact only for power-of-two ipc, so any other
    # ipc pins the witness at t+1 (trace-time check — ipc is static).
    can_run = free & is_cpu
    ipc = float(cfg.cpu_ipc)
    if ipc > 0.0 and math.log2(ipc).is_integer():
        kf = (pool["inst_per_miss"] - st["insts_acc"]) / jnp.float32(ipc)
        k = jnp.maximum(jnp.floor(kf).astype(jnp.int32), 1)
        w_cpu = jnp.min(jnp.where(can_run, t + k, INF))
    else:
        w_cpu = jnp.where(jnp.any(can_run), t1, INF)
    # HWA: the current frame's jittered release point (clamped below by t+1
    # — if already released and still wanting, the event is immediate)
    period = jnp.maximum(pool["dl_period"], 1)
    frame = t1 // period
    rel = frame * period + frame_release_offset(S, frame, pool["dl_jitter"])
    demand = st["period_done"] + st["outstanding"] < pool["dl_reqs"]
    hwa_ok = free & is_hwa & demand & (pool["dl_period"] > 0)
    w_hwa = jnp.min(jnp.where(hwa_ok, jnp.maximum(rel, t1), INF))
    # frame boundary: `deadline_tick` settles every deadline source in the
    # pool at its boundary regardless of `active` (it has no active mask)
    has_dl = pool["dl_period"] > 0
    w_bnd = jnp.min(jnp.where(has_dl, (t // period + 1) * period, INF))
    return jnp.minimum(jnp.minimum(w_gpu, w_cpu), jnp.minimum(w_hwa, w_bnd))


def next_completion(dram: Dict[str, Any], t: jax.Array) -> jax.Array:
    """Earliest cycle > t whose completion-ring slot holds any request.

    Every in-flight request lands within RING cycles of issue, so the ring
    fully describes pending completions."""
    pend = jnp.any(dram["ring"] > 0, axis=1)                 # (RING,)
    slots = jnp.arange(RING, dtype=jnp.int32)
    dt = jnp.mod(slots - (t + 1), RING)                      # 0..RING-1
    return jnp.min(jnp.where(pend, t + 1 + dt, jnp.int32(INF_T)))


def skip_sources(cfg: SimConfig, pool: Dict[str, jax.Array],
                 st: Dict[str, Any], active: jax.Array, k: jax.Array
                 ) -> Dict[str, Any]:
    """Replay k skipped (event-free) cycles of `source_tick` in closed form:
    the two unconditional rng draws per cycle and the CPU instruction
    accrual. Everything else is frozen by the witness contract (no source
    wants, no completions, no boundaries inside the span)."""
    st = dict(st)
    st["rng"] = lcg_skip(st["rng"], 2 * k)
    cls = pool["src_class"]
    mshr = jnp.where(cls == CLS_GPU, cfg.gpu_mshr,
                     jnp.where(cls == CLS_HWA, cfg.hwa_mshr, cfg.cpu_mshr))
    can_run = active & (cls == CLS_CPU) & (st["outstanding"] < mshr) \
        & ~st["pend_valid"]
    add = jnp.where(can_run, k.astype(jnp.float32) * jnp.float32(cfg.cpu_ipc),
                    jnp.float32(0.0))
    st["insts_acc"] = st["insts_acc"] + add
    st["insts_done"] = st["insts_done"] + add
    return st


# ---------------------------------------------------------------------------
# DRAM eligibility + issue
# ---------------------------------------------------------------------------

def eligibility(cfg: SimConfig, dram: Dict[str, Any], c: int,
                bank: jax.Array, row: jax.Array, valid: jax.Array,
                t: jax.Array):
    """Per-candidate issue legality on channel c.

    bank/row/valid: (N,) candidate arrays (bank is bank-in-channel index).
    Returns (eligible (N,), lat (N,), is_hit (N,)).
    """
    tm = cfg.timing
    openv = dram["open_valid"][c][bank]
    openr = dram["open_row"][c][bank]
    is_hit = openv & (openr == row)
    lat = jnp.where(is_hit, tm.lat_hit,
                    jnp.where(openv, tm.lat_conflict, tm.lat_closed)
                    ).astype(jnp.int32)
    ok_bank = dram["bank_free"][c][bank] <= t
    oldest_act = jnp.min(dram["act_ring"][c])
    ok_faw = is_hit | (t - oldest_act >= tm.t_faw)
    ok_bus = t + lat >= dram["bus_free"][c]
    return valid & ok_bank & ok_faw & ok_bus, lat, is_hit


def issue_channels(cfg: SimConfig, dram: Dict[str, Any], st: Dict[str, Any],
                   do_issue: jax.Array, bank: jax.Array, row: jax.Array,
                   src: jax.Array, birth: jax.Array, lat: jax.Array,
                   is_hit: jax.Array, t: jax.Array):
    """Commit at most one issue per channel (all args (C,) vectors).

    Per-channel DRAM rows are disjoint; the per-source scatters (ring, hits,
    issued, sum_lat) use `.add`, which is exact for the integer-valued f32
    accumulators involved, so channels commute.
    """
    tm = cfg.timing
    dram = dict(dram)
    st = dict(st)
    if cfg.validate_enabled:
        # timing compliance is checked against the PRE-update DRAM state
        dram["viol"] = dram["viol"] + validate.issue_counts(
            cfg, dram, do_issue, bank, lat, is_hit, t)
    done = t + lat + tm.t_burst                                 # (C,)
    dram["bank_free"] = masked_set(dram["bank_free"], bank, done, do_issue)
    dram["open_row"] = masked_set(dram["open_row"], bank, row, do_issue)
    dram["open_valid"] = masked_set(dram["open_valid"], bank, True, do_issue)
    # activate bookkeeping (tFAW): replace the oldest entry per channel
    do_act = do_issue & ~is_hit
    amin = jnp.argmin(dram["act_ring"], axis=1)                 # (C,)
    dram["act_ring"] = masked_set(dram["act_ring"], amin, t, do_act)
    dram["bus_free"] = jnp.where(do_issue, done, dram["bus_free"])
    # completion ring: a (RING, S) one-hot mask is heavier than this tiny
    # 1-element-per-channel scatter-add, so the scatter stays
    slot = jnp.mod(done, RING)
    safe_src = jnp.where(do_issue, src, 0)
    dram["ring"] = dram["ring"].at[slot, safe_src].add(
        jnp.where(do_issue, 1, 0))
    dram["hits"] = accum_by_index(dram["hits"], src, 1,
                                  do_issue & is_hit)
    dram["issued"] = accum_by_index(dram["issued"], src, 1, do_issue)
    st["sum_lat"] = accum_by_index(
        st["sum_lat"], src, (done - birth).astype(jnp.float32), do_issue)
    dram = energy.on_issue(cfg, dram, do_issue, src, is_hit, done)
    if cfg.qos_enabled:
        dram["lat_hist"] = qos.on_issue(cfg, dram["lat_hist"], src,
                                        done - birth, do_issue)
    return dram, st


def channel_of(cfg: SimConfig, bank_global: jax.Array) -> jax.Array:
    return jnp.mod(bank_global, cfg.n_channels)


def bank_in_channel(cfg: SimConfig, bank_global: jax.Array) -> jax.Array:
    return bank_global // cfg.n_channels
