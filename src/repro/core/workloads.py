"""Synthetic benchmark pool + multiprogrammed workload construction.

CPU archetypes are calibrated to the paper's Fig 1 ranges for SPEC2006:
MPKI from ~1 (low) to ~40 (high), RBL 0.2–0.9, BLP 1–6. GPU benchmarks have
very high intensity (wavefront generator), RBL ~0.9, BLP ~4. Workload
categories follow §4: L, ML, M, HL, HML, HM, H — 15 workloads each = 105.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.params import CLS_GPU, CLS_HWA, SimConfig, SourcePool

# (name, mpki, rbl, blp)
CPU_BENCH: List[Tuple[str, float, float, int]] = [
    # --- Low (MPKI < 5) ---
    ("l.povray", 1.5, 0.35, 2), ("l.calculix", 3.0, 0.70, 1),
    ("l.namd", 4.0, 0.50, 2), ("l.gcc", 2.0, 0.20, 3),
    ("l.perl", 5.0, 0.85, 1), ("l.sjeng", 4.5, 0.40, 4),
    # --- Medium (5 <= MPKI < 18) ---
    ("m.astar", 8.0, 0.60, 2), ("m.cactus", 11.0, 0.30, 4),
    ("m.zeusmp", 14.0, 0.75, 2), ("m.wrf", 9.0, 0.45, 3),
    ("m.xalanc", 13.0, 0.50, 5), ("m.gems", 16.0, 0.80, 1),
    # --- High (MPKI >= 18) ---
    ("h.omnetpp", 22.0, 0.85, 1), ("h.leslie", 27.0, 0.35, 5),
    ("h.soplex", 33.0, 0.60, 3), ("h.libq", 38.0, 0.45, 6),
    ("h.milc", 25.0, 0.55, 4), ("h.lbm", 40.0, 0.70, 2),
]

# (name, rbl, blp) — intensity is the wavefront generator (MSHR-bounded)
GPU_BENCH: List[Tuple[str, float, int]] = [
    ("g.game0", 0.92, 4), ("g.game1", 0.88, 4), ("g.game2", 0.95, 4),
    ("g.bench0", 0.90, 4), ("g.bench1", 0.93, 4),
]

# (name, dl_period, dl_reqs, rbl, blp, dl_jitter) — frame-deadline HWAs
# (SQUASH-style, arXiv:1505.07502): every dl_period cycles a frame of
# dl_reqs requests is released (after up to dl_jitter cycles of per-frame
# jitter) and is due at the next boundary. Streaming DMA access patterns:
# high RBL, modest BLP.
HWA_BENCH: List[Tuple[str, int, int, float, int, int]] = [
    ("x.imgproc", 1000, 45, 0.85, 2, 64),
    ("x.hog",      800, 28, 0.75, 3, 48),
    ("x.mfilt",   1200, 55, 0.90, 2, 96),
    ("x.ldpc",     600, 18, 0.60, 4, 32),
]

CATEGORIES = ("L", "ML", "M", "HL", "HML", "HM", "H")
_CAT_GROUPS = {
    "L": ("l",), "ML": ("l", "m"), "M": ("m",), "HL": ("h", "l"),
    "HML": ("h", "m", "l"), "HM": ("h", "m"), "H": ("h",),
}


@dataclass(frozen=True)
class Workload:
    category: str
    cpu_ids: Tuple[int, ...]   # indices into CPU_BENCH
    gpu_id: int                # index into GPU_BENCH
    hwa_ids: Tuple[int, ...] = ()   # indices into HWA_BENCH


def make_workloads(n_cpu: int, n_per_cat: int = 15, seed: int = 7,
                   n_hwa: int = 0) -> List[Workload]:
    """`n_hwa > 0` adds that many HWA draws per workload. The draws happen
    only when requested, so the 2-class workload stream for a given seed is
    unchanged by the N-class extension."""
    rng = np.random.RandomState(seed)
    by_group: Dict[str, List[int]] = {"l": [], "m": [], "h": []}
    for i, (name, *_ ) in enumerate(CPU_BENCH):
        by_group[name[0]].append(i)
    out = []
    for cat in CATEGORIES:
        pool = [i for g in _CAT_GROUPS[cat] for i in by_group[g]]
        for _ in range(n_per_cat):
            cpu_ids = tuple(rng.choice(pool, size=n_cpu, replace=True))
            gpu_id = int(rng.randint(len(GPU_BENCH)))
            hwa_ids = tuple(int(rng.randint(len(HWA_BENCH)))
                            for _ in range(n_hwa)) if n_hwa else ()
            out.append(Workload(cat, cpu_ids, gpu_id, hwa_ids))
    return out


def pool_batch(cfg: SimConfig, workloads: Sequence[Workload]
               ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Build (pool arrays (W,S), active (W,S)) for the shared runs."""
    W, S = len(workloads), cfg.n_src
    mpki = np.zeros((W, S), np.float32)
    rbl = np.zeros((W, S), np.float32)
    blp = np.ones((W, S), np.int32)
    is_gpu = np.zeros((W, S), bool)
    src_class = np.zeros((W, S), np.int32)          # CLS_CPU default
    dl_period = np.zeros((W, S), np.int32)
    dl_reqs = np.zeros((W, S), np.int32)
    dl_jitter = np.zeros((W, S), np.int32)
    for w, wl in enumerate(workloads):
        for i, b in enumerate(wl.cpu_ids[:cfg.n_cpu]):
            _, m, r, bl = CPU_BENCH[b]
            mpki[w, i], rbl[w, i], blp[w, i] = m, r, bl
        gname, gr, gb = GPU_BENCH[wl.gpu_id]
        gi = cfg.n_cpu
        mpki[w, gi], rbl[w, gi], blp[w, gi] = 1000.0, gr, gb
        is_gpu[w, gi] = True
        src_class[w, gi] = CLS_GPU
        for j, b in enumerate(wl.hwa_ids[:cfg.n_hwa]):
            _, period, reqs, r, bl, jit = HWA_BENCH[b]
            hi = cfg.n_cpu + cfg.n_gpu + j
            mpki[w, hi], rbl[w, hi], blp[w, hi] = 1000.0, r, bl
            src_class[w, hi] = CLS_HWA
            dl_period[w, hi], dl_reqs[w, hi] = period, reqs
            dl_jitter[w, hi] = jit
    pool = {"mpki": mpki,
            "inst_per_miss": np.maximum(1000.0 / np.maximum(mpki, 1e-3), 1.0),
            "rbl": rbl, "blp": blp, "is_gpu": is_gpu,
            "src_class": src_class, "dl_period": dl_period,
            "dl_reqs": dl_reqs, "dl_jitter": dl_jitter}
    active = np.ones((W, S), bool)
    return pool, active


# ---------------------------------------------------------------------------
# idle-heavy / bursty archetypes: the traffic the variable-step driver is
# for (ISSUE 7 / ROADMAP open item 2). Real heterogeneous streams are mostly
# idle at the memory controller (Ausavarungnirun, arXiv:1803.06958; Mutlu et
# al., arXiv:1805.06407): sparse CPU misses, long HWA frame gaps, duty-cycled
# GPU bursts. Each archetype is one workload row; the measured skip ratio
# per archetype is reported by `benchmarks/simspeed.py` (event_skip section).
# ---------------------------------------------------------------------------

BURSTY_ARCHETYPES: Tuple[str, ...] = (
    "idle_cpu",      # low-intensity CPU mix, nothing else
    "hwa_frames",    # long-period frame accelerators + a CPU trickle
    "gpu_burst",     # duty-cycled streaming bursts (GPU-like HWA source)
    "mixed_bursty",  # all three combined
)


def bursty_batch(cfg: SimConfig) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """(pool (W,S), active (W,S)) for the BURSTY_ARCHETYPES rows.

    Duty-cycled GPU bursts are modeled as CLS_HWA sources (streaming RBL/BLP
    with a large per-frame request budget): the frame machinery IS the duty
    cycle — a dl_reqs burst every dl_period cycles, idle in between — which
    a plain CLS_GPU source (always-wanting) cannot express. Requires at
    least two HWA slots (cfg.n_hwa >= 2).
    """
    if cfg.n_hwa < 2:
        raise ValueError("bursty_batch needs cfg.n_hwa >= 2 "
                         f"(got {cfg.n_hwa})")
    W, S = len(BURSTY_ARCHETYPES), cfg.n_src
    mpki = np.zeros((W, S), np.float32)
    rbl = np.zeros((W, S), np.float32)
    blp = np.ones((W, S), np.int32)
    is_gpu = np.zeros((W, S), bool)
    src_class = np.zeros((W, S), np.int32)
    dl_period = np.zeros((W, S), np.int32)
    dl_reqs = np.zeros((W, S), np.int32)
    dl_jitter = np.zeros((W, S), np.int32)
    active = np.zeros((W, S), bool)

    def cpu(w, i, m, r=0.6, bl=2):
        mpki[w, i], rbl[w, i], blp[w, i] = m, r, bl
        active[w, i] = True

    def hwa(w, j, period, reqs, r, bl, jit):
        hi = cfg.n_cpu + cfg.n_gpu + j
        mpki[w, hi], rbl[w, hi], blp[w, hi] = 1000.0, r, bl
        src_class[w, hi] = CLS_HWA
        dl_period[w, hi], dl_reqs[w, hi] = period, reqs
        dl_jitter[w, hi] = jit
        active[w, hi] = True

    for w, arch in enumerate(BURSTY_ARCHETYPES):
        if arch == "idle_cpu":
            # sparse misses: one every ~500-3300 instructions per core
            for i, m in zip(range(cfg.n_cpu), (0.3, 0.6, 1.2, 2.0) * 4):
                cpu(w, i, m)
        elif arch == "hwa_frames":
            cpu(w, 0, 0.5)
            hwa(w, 0, 4000, 60, 0.85, 2, 128)
            hwa(w, 1, 6000, 40, 0.90, 2, 256)
        elif arch == "gpu_burst":
            cpu(w, 0, 0.3)
            # ~300-cycle burst every 3000 cycles at ~1 req/cycle drain
            hwa(w, 0, 3000, 300, 0.92, 4, 0)
        elif arch == "mixed_bursty":
            for i, m in zip(range(min(cfg.n_cpu, 3)), (0.5, 1.0, 1.5)):
                cpu(w, i, m)
            hwa(w, 0, 5000, 50, 0.85, 2, 192)
            hwa(w, 1, 2500, 200, 0.90, 4, 0)
    pool = {"mpki": mpki,
            "inst_per_miss": np.maximum(1000.0 / np.maximum(mpki, 1e-3), 1.0),
            "rbl": rbl, "blp": blp, "is_gpu": is_gpu,
            "src_class": src_class, "dl_period": dl_period,
            "dl_reqs": dl_reqs, "dl_jitter": dl_jitter}
    return pool, active


def alone_batch(cfg: SimConfig) -> Tuple[Dict[str, np.ndarray], np.ndarray,
                                         Dict[str, int]]:
    """One single-source run per benchmark; returns index map name->row.

    HWA rows are added only when the config has HWA slots (cfg.n_hwa > 0),
    keeping the 2-class alone sweep — and its cached results — untouched.
    """
    names = [b[0] for b in CPU_BENCH] + [g[0] for g in GPU_BENCH]
    if cfg.n_hwa > 0:
        names += [h[0] for h in HWA_BENCH]
    W, S = len(names), cfg.n_src
    mpki = np.full((W, S), 10.0, np.float32)
    rbl = np.full((W, S), 0.5, np.float32)
    blp = np.ones((W, S), np.int32)
    is_gpu = np.zeros((W, S), bool)
    src_class = np.zeros((W, S), np.int32)
    dl_period = np.zeros((W, S), np.int32)
    dl_reqs = np.zeros((W, S), np.int32)
    dl_jitter = np.zeros((W, S), np.int32)
    active = np.zeros((W, S), bool)
    for w, name in enumerate(names):
        if name.startswith("g."):
            _, r, bl = GPU_BENCH[[g[0] for g in GPU_BENCH].index(name)]
            gi = cfg.n_cpu
            mpki[w, gi], rbl[w, gi], blp[w, gi] = 1000.0, r, bl
            is_gpu[w, gi] = True
            src_class[w, gi] = CLS_GPU
            active[w, gi] = True
        elif name.startswith("x."):
            _, period, reqs, r, bl, jit = \
                HWA_BENCH[[h[0] for h in HWA_BENCH].index(name)]
            hi = cfg.n_cpu + cfg.n_gpu
            mpki[w, hi], rbl[w, hi], blp[w, hi] = 1000.0, r, bl
            src_class[w, hi] = CLS_HWA
            dl_period[w, hi], dl_reqs[w, hi] = period, reqs
            dl_jitter[w, hi] = jit
            active[w, hi] = True
        else:
            _, m, r, bl = CPU_BENCH[[b[0] for b in CPU_BENCH].index(name)]
            mpki[w, 0], rbl[w, 0], blp[w, 0] = m, r, bl
            active[w, 0] = True
    pool = {"mpki": mpki,
            "inst_per_miss": np.maximum(1000.0 / np.maximum(mpki, 1e-3), 1.0),
            "rbl": rbl, "blp": blp, "is_gpu": is_gpu,
            "src_class": src_class, "dl_period": dl_period,
            "dl_reqs": dl_reqs, "dl_jitter": dl_jitter}
    return pool, active, {n: i for i, n in enumerate(names)}


def alone_perf_lookup(cfg: SimConfig, metrics: Dict[str, np.ndarray],
                      name_to_row: Dict[str, int]):
    """Extract per-benchmark alone performance from the alone-batch metrics."""
    out = {}
    for name, w in name_to_row.items():
        if name.startswith("g."):
            out[name] = float(metrics["bw"][w, cfg.n_cpu])
        elif name.startswith("x."):
            out[name] = float(metrics["bw"][w, cfg.n_cpu + cfg.n_gpu])
        else:
            out[name] = float(metrics["ipc"][w, 0])
    return out
