"""Cycle-level heterogeneous memory-system simulator (the paper's system).

Layout:
  params      static DRAM timing + structure/policy knobs (`SimConfig`)
  engine      shared machinery: sources, DRAM state, eligibility, issue
  policy      the `MemoryPolicy` protocol + `Registry` (the scheduler API)
  policies/   built-in registered policies, one module each
              (frfcfs, atlas, parbs, tcm, sms, sms_dash, bliss, squash_prio)
  schedulers  centralized CAM-buffer substrate (`CentralizedPolicy` base)
  sms         the staged scheduler's three stages
  simulator   scan/vmap drivers generic over any registered policy
  workloads / metrics / power   figure-reproduction support

Subpackages beside `core` host the other substrates (serving, kernels, ...);
`repro.serving.scheduler` reuses `policy.Registry` so both domains enumerate
schedulers the same way.
"""
