"""Per-cycle invariant sanitizer: the third measurement-only subsystem.

The paper's low-level MC task is DRAM command scheduling "while ensuring
compliance with all DRAM timing and power constraints" (§1). Golden digests
pin drift against recorded traces but cannot localize a violation and cover
nothing at new knob points or workloads — this module turns the contracts
into *checked properties*. Gated by ``cfg.validate_enabled`` (default off),
it accumulates int32 violation counters in ``dram_state["viol"]``:

  * DRAM timing compliance — an issue committed to a busy bank, an ACTIVATE
    inside a saturated tFAW window, a burst scheduled before the shared bus
    frees (checked on the pre-update state inside `engine.issue_channels`);
  * conservation laws — per-source ``emitted == completed + outstanding``,
    total ``outstanding == pending + queued + in-flight``, policy structure
    occupancy within declared bounds (via the per-policy hooks below),
    ``sb_cycles + pd_cycles == cycles`` per channel, ``lat_hist`` row sums
    == ``issued``, ``frames_released == dl_met + dl_missed``, and the
    engine rng stream sitting at its closed-form position;
  * a skip-witness lateness auditor for the variable-step driver — any
    event that *would have fired* inside a jumped span is counted, turning
    the ROADMAP's "conservative-early, never late" rule from a convention
    into a checked property.

Same contract as energy/qos: counters never feed back into scheduling, so
flipping the flag cannot change a decision, and OFF adds zero primitives to
the per-cycle jaxpr (pinned in tests/test_perf_invariants.py). ON may use
gathers — it is a debug mode, not a hot path.

Auditor design note: the auditor must NOT re-evaluate the driver's witness
formulas at the base cycle on post-span state (closed-form accruals like
``insts_acc += k*ipc`` make those formulas report *past* crossings — a
false positive whenever the audited witness was the binding minimum).
Instead it checks direct would-fire predicates at the last skipped cycle
``u = t_new - 1`` — valid because readiness predicates are monotone in t
while span state is frozen — plus closed-form whole-span checks for frame
boundaries and completion-ring slots.

Per-policy hooks (all optional; see ROADMAP "Validation & fault-injection
contract"):

  * ``queued_requests(cfg, sched) -> i32`` — requests held in policy
    structures (buffer/FIFOs/DCS), feeding the total-flow conservation law;
  * ``check_invariants(cfg, pool, st, sched, t) -> i32`` — count of
    violated structure invariants (occupancy bounds, mirror-counter
    recounts, policy rng stream position);
  * ``audit_skip(cfg, pool, st, sched, dram, t, t_new) -> {name: i32}`` —
    policy-side lateness checks for a jumped span (admission readiness,
    issue eligibility, policy boundaries), merged into the ``late_*``
    counters.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import CLS_CPU, CLS_GPU, CLS_HWA, SimConfig

# counter layout of dram_state["viol"] — order is part of the metric schema
VIOLATIONS = (
    "busy_bank",        # issue committed to a bank before bank_free
    "tfaw",             # ACTIVATE inside a saturated four-ACT window
    "bus_conflict",     # data burst scheduled before the shared bus frees
    "req_conserve",     # per-source emitted != completed + outstanding
    "flow_conserve",    # outstanding != pending + queued + in-flight
    "occupancy",        # policy structure bounds / mirror counters broken
    "energy_bg",        # sb_cycles + pd_cycles != elapsed cycles (per chan)
    "lat_hist",         # latency histogram row sum != issued
    "frames",           # frames_released != dl_met + dl_missed
    "rng_stream",       # engine rng off its closed-form stream position
    "late_source",      # skip span jumped past a source emission
    "late_completion",  # skip span jumped past a completion-ring slot
    "late_admission",   # skip span jumped past an admission-ready cycle
    "late_issue",       # skip span jumped past an issue-eligible cycle
    "late_boundary",    # skip span jumped past a frame/policy boundary
)
NV = len(VIOLATIONS)
IDX = {n: i for i, n in enumerate(VIOLATIONS)}

# dram_state keys owned by this module (digest whitelists key off this)
STATE_KEYS = ("viol",)


def validate_state(cfg: SimConfig) -> Dict[str, Any]:
    """Sanitizer counters for `engine.dram_state` ({} when disabled)."""
    if not cfg.validate_enabled:
        return {}
    return {"viol": jnp.zeros((NV,), jnp.int32)}


def bump(counts: Dict[str, Any]) -> jax.Array:
    """Assemble an (NV,) increment vector from named counts (missing = 0)."""
    unknown = set(counts) - set(VIOLATIONS)
    assert not unknown, f"unknown violation counters: {sorted(unknown)}"
    return jnp.stack([jnp.asarray(counts.get(n, 0), jnp.int32).reshape(())
                      for n in VIOLATIONS])


def _nbool(x) -> jax.Array:
    return jnp.sum(jnp.asarray(x, jnp.int32))


# ---------------------------------------------------------------------------
# DRAM timing compliance (called from engine.issue_channels, PRE-update)
# ---------------------------------------------------------------------------

def issue_counts(cfg: SimConfig, dram: Dict[str, Any], do_issue: jax.Array,
                 bank: jax.Array, lat: jax.Array, is_hit: jax.Array,
                 t: jax.Array) -> jax.Array:
    """Timing-violation increments for one issue commit (all args (C,)).

    Reads the pre-update DRAM state: a correct scheduler only sets
    `do_issue` on candidates that passed `engine.eligibility`, so each
    check here re-derives one eligibility gate independently.
    """
    tm = cfg.timing
    bank_free = jnp.take_along_axis(dram["bank_free"], bank[:, None],
                                    axis=1)[:, 0]
    busy = do_issue & (bank_free > t)
    oldest_act = jnp.min(dram["act_ring"], axis=1)
    faw = do_issue & ~is_hit & (t - oldest_act < tm.t_faw)
    bus = do_issue & (t + lat < dram["bus_free"])
    return bump({"busy_bank": _nbool(busy), "tfaw": _nbool(faw),
                 "bus_conflict": _nbool(bus)})


# ---------------------------------------------------------------------------
# end-of-cycle conservation laws
# ---------------------------------------------------------------------------

def tick_counts(cfg: SimConfig, pool: Dict[str, jax.Array], pol,
                st: Dict[str, Any], sched: Dict[str, Any],
                dram: Dict[str, Any], t: jax.Array) -> jax.Array:
    """Conservation-law increments, evaluated on post-step state at cycle t.

    Each law is an exact identity of the update rules — any nonzero count
    localizes a broken bookkeeping site, not a modeling choice.
    """
    from repro.core import engine

    c: Dict[str, Any] = {}
    c["req_conserve"] = _nbool(
        st["emitted"] - st["completed"] != st["outstanding"])

    qfn = getattr(pol, "queued_requests", None)
    if qfn is not None:
        total = jnp.sum(st["outstanding"])
        held = (jnp.sum(st["pend_valid"].astype(jnp.int32))
                + qfn(cfg, sched) + jnp.sum(dram["ring"]))
        c["flow_conserve"] = _nbool(total != held)

    chk = getattr(pol, "check_invariants", None)
    if chk is not None:
        c["occupancy"] = chk(cfg, pool, st, sched, t)

    if "sb_cycles" in dram:
        c["energy_bg"] = _nbool(dram["sb_cycles"] + dram["pd_cycles"] != t + 1)
    if "lat_hist" in dram:
        c["lat_hist"] = _nbool(
            jnp.sum(dram["lat_hist"], axis=-1) != dram["issued"])
    c["frames"] = _nbool(
        st["frames_released"] != st["dl_met"] + st["dl_missed"])

    # the engine rng stream is a pure function of t (2 draws per cycle,
    # ticked or skipped) — catches fast-forward off-by-ones exactly
    rng0 = (jnp.arange(cfg.n_src, dtype=jnp.uint32) * jnp.uint32(2654435761)
            + jnp.uint32(12345))
    expect = engine.lcg_skip(rng0, 2 * (t + 1))
    c["rng_stream"] = _nbool(st["rng"] != expect)
    return bump(c)


# ---------------------------------------------------------------------------
# skip-witness lateness auditor (variable-step driver)
# ---------------------------------------------------------------------------

def span_counts(cfg: SimConfig, pool: Dict[str, jax.Array], pol,
                st: Dict[str, Any], sched: Dict[str, Any],
                dram: Dict[str, Any], active: jax.Array,
                t: jax.Array, t_new: jax.Array) -> jax.Array:
    """Lateness increments for the jumped span (t, t_new), evaluated after
    the closed-form accruals. Any would-fire event strictly inside the span
    is a witness-contract violation (the driver may only jump over cycles
    where every hook is a no-op beyond the replayed accruals)."""
    from repro.core import engine

    S = cfg.n_src
    k = t_new - t - 1                    # number of skipped cycles
    skipped = k >= 1
    u = t_new - 1                        # last skipped cycle

    cls = pool["src_class"]
    mshr = jnp.where(cls == CLS_GPU, cfg.gpu_mshr,
                     jnp.where(cls == CLS_HWA, cfg.hwa_mshr, cfg.cpu_mshr))
    free = active & ~st["pend_valid"] & (st["outstanding"] < mshr)
    # would-fire emission predicates at u on post-accrual state. Monotone in
    # t with span state frozen, so firing anywhere in the span implies
    # firing at u; conversely a correct driver guarantees not-at-u.
    want_cpu = free & (cls == CLS_CPU) & \
        (st["insts_acc"] >= pool["inst_per_miss"])
    want_gpu = free & (cls == CLS_GPU)
    period = jnp.maximum(pool["dl_period"], 1)
    released = jnp.mod(u, period) >= \
        engine.frame_release_offset(S, u // period, pool["dl_jitter"])
    want_hwa = free & (cls == CLS_HWA) & released & \
        (st["period_done"] + st["outstanding"] < pool["dl_reqs"])
    c: Dict[str, Any] = {
        "late_source": jnp.where(
            skipped, _nbool(want_cpu | want_gpu | want_hwa), 0)}

    # completions due strictly inside the span: ring slot t+1+dt with
    # dt = (slot - (t+1)) mod RING and dt < min(k, RING)
    slots = jnp.arange(engine.RING, dtype=jnp.int32)
    dt = jnp.mod(slots - (t + 1), engine.RING)
    pend = jnp.any(dram["ring"] > 0, axis=1)
    c["late_completion"] = _nbool(pend & (dt < jnp.minimum(k, engine.RING)))

    # frame boundaries crossed inside [t+1, u]
    has_dl = pool["dl_period"] > 0
    c["late_boundary"] = _nbool(has_dl & (u // period > t // period))

    afn = getattr(pol, "audit_skip", None)
    if afn is not None:
        for name, n in afn(cfg, pool, st, sched, dram, t, t_new).items():
            c[name] = c.get(name, 0) + jnp.asarray(n, jnp.int32)
    return bump(c)


# ---------------------------------------------------------------------------
# host-side helpers
# ---------------------------------------------------------------------------

def summarize(v) -> Dict[str, int]:
    """Collapse a violations array of shape (..., NV) to {name: total}."""
    arr = np.asarray(v).astype(np.int64).reshape(-1, NV).sum(axis=0)
    return {n: int(x) for n, x in zip(VIOLATIONS, arr)}


def debug_check(cfg: SimConfig, policy, pool, active, n_cycles: int = 2_000,
                skip: bool = False):
    """Hard-fail debug mode on the solo path: run with the sanitizer on and
    `checkify`-raise at the first cycle whose violation counters go nonzero
    (instead of silently accumulating). Returns the final carry on success.
    """
    from jax.experimental import checkify

    from repro.core import policy as policy_api
    from repro.core import simulator as sim

    if not cfg.validate_enabled:
        cfg = cfg.replace(validate_enabled=True)
    pool = sim.prepare_pool(pool, (cfg.n_src,))
    bcfg, pol, carry = sim._init(cfg, policy)
    active = jnp.asarray(active, bool)
    step = policy_api.make_step(bcfg, pol, pool, active)
    skip_body = policy_api.make_skip_step(bcfg, pol, pool, active) \
        if skip else None

    def checked(carry, t):
        if skip_body is None:
            carry, _ = step(carry, t)
            t_new = t + 1
        else:
            carry, t_new = skip_body(carry, t, jnp.int32(n_cycles))
        checkify.check(jnp.all(carry[2]["viol"] == 0),
                       "invariant violation at cycle {t}: counters {v}",
                       t=t, v=carry[2]["viol"])
        return carry, t_new

    def run(carry):
        if skip_body is None:
            return jax.lax.scan(
                checked, carry, jnp.arange(n_cycles, dtype=jnp.int32))[0]

        def body(state):
            carry, t = state
            return checked(carry, t)

        return jax.lax.while_loop(
            lambda s: s[1] < n_cycles, body, (carry, jnp.int32(0)))[0]

    err, final = jax.jit(checkify.checkify(run))(carry)
    err.throw()
    return jax.tree_util.tree_map(np.asarray, final)
