"""Centralized request-buffer substrate for `MemoryPolicy` implementations.

The FR-FCFS family (FR-FCFS, ATLAS, PAR-BS, TCM, BLISS, SQUASH-prio, ...)
shares one structure — a per-channel CAM-style request buffer scored every
cycle — exactly the monolithic design SMS decomposes. This module provides
that substrate as `CentralizedPolicy`, a base class for the protocol in
`repro.core.policy`: subclasses (one module each under
`repro.core.policies/`) override

    extra_state(cfg)                       policy-private state arrays
    boundary_pred(cfg, pool, st, buf, t)   scalar bool: run boundary_tick?
                                           (None = policy has no boundary)
    boundary_tick(cfg, pool, st, buf, t)   epoch/quantum/batch maintenance,
                                           executed under `lax.cond`
    policy_tick(cfg, pool, st, buf, t)     cheap per-cycle maintenance
    score(cfg, pool, buf, is_hit, t)       (C, E) int32 lexicographic score
    on_admit(cfg, pool, st, buf, do, slot, src, t)   per-admission hook
    on_issue(cfg, pool, buf, do, pick, src, t)       per-issue hook (buf is
                                                     the PRE-clear buffer)

Hot-loop contract (see ROADMAP "hot-loop rules"): anything that sorts or
ranks belongs in `boundary_tick`. A predicate that depends only on the
scan's scalar cycle counter `t` stays unbatched under `vmap`, so the cond
branch genuinely executes once per epoch; a data-dependent predicate
degrades to `select` under `vmap` but still keeps the sort out of the
unbatched per-cycle jaxpr. The default `score` adds a cached per-source
priority (`buf["pri_src"]`, computed by `boundary_tick`) to the FR-FCFS
base score, so no subclass ranks in `score`.

Scores are lexicographic integers:

    [policy bits 22+] [rank 15..20] [row-hit 14] [age 0..13]

Buffer shapes: (C, E). Admission is one request per channel per cycle
(single MC ingress port); half the entries are reserved for CPU sources
(the paper's anti-starvation provisioning, §4): GPU occupancy is tracked
by the incrementally-maintained `gpu_occ` counter (admit +1, issue -1)
instead of an O(C·E) reduction each cycle. Admission and issue are
expressed as whole-(C, ...) array ops — channels never appear as a Python
loop, so trace size is independent of `n_channels`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.params import SimConfig

AGE_CAP = (1 << 14) - 1
HIT_BIT = 1 << 14
RANK_SHIFT = 15
POL_BIT = 1 << 22


def buffer_state(cfg: SimConfig) -> Dict[str, Any]:
    """The shared CAM buffer; policy-private arrays live in extra_state.

    `gpu_occ` mirrors `sum(valid & is_gpu_src[src])` per channel — admit
    increments it, issue decrements it — so the CPU-reservation check never
    re-scans the buffer.
    """
    C, E = cfg.n_channels, cfg.buf_entries
    z = lambda dt: jnp.zeros((C, E), dt)
    return {
        "valid": z(bool), "src": z(jnp.int32), "bank": z(jnp.int32),
        "row": z(jnp.int32), "birth": z(jnp.int32), "marked": z(bool),
        "gpu_occ": jnp.zeros((C,), jnp.int32),
    }


def rank_pos(key: jax.Array) -> jax.Array:
    """rank position of each element under ascending sort (0 = smallest)."""
    return jnp.argsort(jnp.argsort(key)).astype(jnp.int32)


def base_score(cfg: SimConfig, buf, is_hit, t) -> jax.Array:
    """FR-FCFS core: row hit above age. (C, E) int32."""
    age = jnp.clip(t - buf["birth"], 0, AGE_CAP)
    return is_hit.astype(jnp.int32) * HIT_BIT + age


def admit(cfg: SimConfig, pool, st, buf, t, key=None):
    """One admission per channel per cycle; lowest-key pending request wins
    (default key: birth, i.e. oldest first).

    Enforces the CPU reservation: GPU sources are blocked while they hold
    >= gpu_cap entries in that channel's buffer (tracked by the `gpu_occ`
    counter). Sources map to exactly one channel, so all channels admit
    independently in one batched op.

    Returns (st, buf, do, slot, src): per-channel admission outcome for
    `on_admit` hooks.
    """
    S, C = cfg.n_src, cfg.n_channels
    is_gpu_src = pool["is_gpu"]
    st = dict(st)
    buf = dict(buf)
    cidx = jnp.arange(C)
    ch = engine.channel_of(cfg, st["pend_bank"])                # (S,)
    gpu_ok = buf["gpu_occ"] < cfg.gpu_cap
    cand = st["pend_valid"][None, :] & (ch[None, :] == cidx[:, None]) \
        & (gpu_ok[:, None] | ~is_gpu_src[None, :])              # (C, S)
    has_free = ~jnp.all(buf["valid"], axis=1)                   # (C,)
    key = st["pend_birth"] if key is None else key
    key = jnp.where(cand, key[None, :], jnp.int32(2**30))
    s = jnp.argmin(key, axis=1)                                 # (C,)
    do = cand[cidx, s] & has_free
    slot = jnp.argmin(buf["valid"], axis=1)                     # first free
    wr = lambda a, v: engine.masked_set(a, slot, v, do)
    buf["valid"] = wr(buf["valid"], True)
    buf["src"] = wr(buf["src"], s.astype(jnp.int32))
    buf["bank"] = wr(buf["bank"], engine.bank_in_channel(cfg,
                                                         st["pend_bank"][s]))
    buf["row"] = wr(buf["row"], st["pend_row"][s])
    buf["birth"] = wr(buf["birth"], st["pend_birth"][s])
    buf["marked"] = wr(buf["marked"], False)
    buf["gpu_occ"] = buf["gpu_occ"] + \
        (do & is_gpu_src[s]).astype(jnp.int32)
    taken = jnp.any((jnp.arange(S) == s[:, None]) & do[:, None], axis=0)
    st["pend_valid"] = st["pend_valid"] & ~taken
    return st, buf, do, slot, s.astype(jnp.int32)


class CentralizedPolicy:
    """`MemoryPolicy` base for single-stage CAM-buffer schedulers.

    The per-cycle step is split in two: `policy_tick` runs every cycle and
    must stay cheap (no sorts, no O(C·E) reductions for incrementally
    maintainable state); `boundary_tick` holds the epoch/quantum/batch
    maintenance — ranking sorts included — and executes under `lax.cond`
    gated on `boundary_pred`.
    """

    name = "centralized"
    variant_of = None

    # keys `boundary_tick` may WRITE. The cond's operands/outputs are
    # restricted to these (everything else is read through the closure), so
    # the per-cycle step never copies or selects untouched (C, E) arrays
    # through the conditional. Keep this to the small (S,)-shaped state.
    boundary_keys: tuple = ()

    # -- per-policy hooks --------------------------------------------------
    def extra_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {}

    def pre_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Per-cycle maintenance that must run BEFORE the boundary gate
        (state that `boundary_pred`/`boundary_tick` read). Sort-free."""
        return buf

    def boundary_pred(self, cfg: SimConfig, pool, st, buf, t):
        """Scalar bool gating `boundary_tick`; None = no boundary work.

        Predicates that depend only on `t` stay unbatched under `vmap`, so
        the gated branch truly runs once per epoch.
        """
        return None

    def boundary_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Cond-gated maintenance: rank recomputes, shuffles. May read any
        state but only write `boundary_keys`."""
        return buf

    def policy_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Unconditional per-cycle maintenance; keep it sort-free."""
        return buf

    def score(self, cfg: SimConfig, pool, buf, is_hit, t) -> jax.Array:
        """Default: cached per-source priority + FR-FCFS base score."""
        s = base_score(cfg, buf, is_hit, t)
        if "pri_src" in buf:
            s = buf["pri_src"][buf["src"]] + s
        return s

    def on_admit(self, cfg: SimConfig, pool, st, buf, do, slot, src, t):
        """Per-admission accounting ((C,) vectors, after the buffer write)."""
        return buf

    def on_issue(self, cfg: SimConfig, pool, buf, do, pick, src, t):
        """Per-issue accounting. `buf` is PRE-clear: entry `pick` still
        holds the issued request's fields."""
        return buf

    def admit_key(self, cfg: SimConfig, pool, st, buf, t):
        """(S,) admission ordering key, lowest first (default: oldest)."""
        return st["pend_birth"]

    # -- MemoryPolicy protocol ---------------------------------------------
    def configure(self, cfg: SimConfig) -> SimConfig:
        return cfg

    def init_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {**buffer_state(cfg), **self.extra_state(cfg)}

    def tick(self, cfg: SimConfig, pool, st, buf, t):
        st, buf, do, slot, src = admit(
            cfg, pool, st, buf, t,
            key=self.admit_key(cfg, pool, st, buf, t))
        buf = self.on_admit(cfg, pool, st, buf, do, slot, src, t)
        buf = self.pre_tick(cfg, pool, st, buf, t)
        pred = self.boundary_pred(cfg, pool, st, buf, t)
        if pred is not None:
            keys = self.boundary_keys

            def run(sub):
                new = self.boundary_tick(cfg, pool, st, {**buf, **sub}, t)
                return {k: new[k] for k in keys}

            sub = jax.lax.cond(pred, run, lambda s: s,
                               {k: buf[k] for k in keys})
            buf = {**buf, **sub}
        buf = self.policy_tick(cfg, pool, st, buf, t)
        return st, buf

    def select(self, cfg: SimConfig, pool, st, buf, dram, t):
        """Pick + issue at most one request per channel (all channels at
        once; cross-channel state only meets in commutative scatter-adds)."""
        C = cfg.n_channels
        cidx = jnp.arange(C)
        elig, lat, is_hit = jax.vmap(
            lambda c, bank, row, valid: engine.eligibility(
                cfg, dram, c, bank, row, valid, t)
        )(cidx, buf["bank"], buf["row"], buf["valid"])          # (C, E) each
        score = self.score(cfg, pool, buf, is_hit, t)
        score = jnp.where(elig, score, -1)
        pick = jnp.argmax(score, axis=1)                        # (C,)
        at_pick = lambda a: jnp.take_along_axis(a, pick[:, None], 1)[:, 0]
        do = at_pick(score) >= 0
        src = at_pick(buf["src"])
        dram, st = engine.issue_channels(
            cfg, dram, st, do, at_pick(buf["bank"]), at_pick(buf["row"]),
            src, at_pick(buf["birth"]), at_pick(lat), at_pick(is_hit), t)
        buf = self.on_issue(cfg, pool, buf, do, pick, src, t)
        buf = dict(buf)
        clear = lambda a: engine.masked_set(a, pick, False, do)
        buf["valid"] = clear(buf["valid"])
        buf["marked"] = clear(buf["marked"])
        buf["gpu_occ"] = buf["gpu_occ"] - \
            (do & pool["is_gpu"][src]).astype(jnp.int32)
        return st, buf, dram
