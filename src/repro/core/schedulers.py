"""Centralized request-buffer substrate for `MemoryPolicy` implementations.

The FR-FCFS family (FR-FCFS, ATLAS, PAR-BS, TCM, BLISS, SQUASH-prio, ...)
shares one structure — a per-channel CAM-style request buffer scored every
cycle — exactly the monolithic design SMS decomposes. This module provides
that substrate as `CentralizedPolicy`, a base class for the protocol in
`repro.core.policy`: subclasses (one module each under
`repro.core.policies/`) override

    extra_state(cfg)                  policy-private state arrays
    policy_tick(cfg, pool, st, buf, t)    periodic maintenance (epochs,
                                          quanta, batch remarking, ...)
    score(cfg, pool, buf, is_hit, t)      (C, E) int32 lexicographic score
    on_issue(cfg, pool, buf, do, src, t)  per-issue accounting hooks

Scores are lexicographic integers:

    [policy bits 22+] [rank 15..20] [row-hit 14] [age 0..13]

Buffer shapes: (C, E). Admission is one request per channel per cycle
(single MC ingress port); half the entries are reserved for CPU sources
(the paper's anti-starvation provisioning, §4). Admission and issue are
expressed as whole-(C, ...) array ops — channels never appear as a Python
loop, so trace size is independent of `n_channels`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.params import SimConfig

AGE_CAP = (1 << 14) - 1
HIT_BIT = 1 << 14
RANK_SHIFT = 15
POL_BIT = 1 << 22


def buffer_state(cfg: SimConfig) -> Dict[str, Any]:
    """The shared CAM buffer; policy-private arrays live in extra_state."""
    C, E = cfg.n_channels, cfg.buf_entries
    z = lambda dt: jnp.zeros((C, E), dt)
    return {
        "valid": z(bool), "src": z(jnp.int32), "bank": z(jnp.int32),
        "row": z(jnp.int32), "birth": z(jnp.int32), "marked": z(bool),
    }


def rank_pos(key: jax.Array) -> jax.Array:
    """rank position of each element under ascending sort (0 = smallest)."""
    return jnp.argsort(jnp.argsort(key)).astype(jnp.int32)


def base_score(cfg: SimConfig, buf, is_hit, t) -> jax.Array:
    """FR-FCFS core: row hit above age. (C, E) int32."""
    age = jnp.clip(t - buf["birth"], 0, AGE_CAP)
    return is_hit.astype(jnp.int32) * HIT_BIT + age


def admit(cfg: SimConfig, pool, st, buf, t, key=None):
    """One admission per channel per cycle; lowest-key pending request wins
    (default key: birth, i.e. oldest first).

    Enforces the CPU reservation: GPU sources are blocked while they hold
    >= gpu_cap entries in that channel's buffer. Sources map to exactly one
    channel, so all channels admit independently in one batched op.
    """
    S, C = cfg.n_src, cfg.n_channels
    is_gpu_src = pool["is_gpu"]
    st = dict(st)
    buf = dict(buf)
    cidx = jnp.arange(C)
    ch = engine.channel_of(cfg, st["pend_bank"])                # (S,)
    gpu_cnt = jnp.sum(buf["valid"] & is_gpu_src[buf["src"]], axis=1)  # (C,)
    gpu_ok = gpu_cnt < cfg.gpu_cap
    cand = st["pend_valid"][None, :] & (ch[None, :] == cidx[:, None]) \
        & (gpu_ok[:, None] | ~is_gpu_src[None, :])              # (C, S)
    has_free = ~jnp.all(buf["valid"], axis=1)                   # (C,)
    key = st["pend_birth"] if key is None else key
    key = jnp.where(cand, key[None, :], jnp.int32(2**30))
    s = jnp.argmin(key, axis=1)                                 # (C,)
    do = cand[cidx, s] & has_free
    slot = jnp.argmin(buf["valid"], axis=1)                     # first free
    safe = jnp.where(do, slot, 0)
    wr = lambda a, v: a.at[cidx, safe].set(jnp.where(do, v, a[cidx, safe]))
    buf["valid"] = wr(buf["valid"], True)
    buf["src"] = wr(buf["src"], s.astype(jnp.int32))
    buf["bank"] = wr(buf["bank"], engine.bank_in_channel(cfg,
                                                         st["pend_bank"][s]))
    buf["row"] = wr(buf["row"], st["pend_row"][s])
    buf["birth"] = wr(buf["birth"], st["pend_birth"][s])
    buf["marked"] = wr(buf["marked"], False)
    st["pend_valid"] = st["pend_valid"].at[
        jnp.where(do, s, S)].set(False, mode="drop")
    return st, buf


class CentralizedPolicy:
    """`MemoryPolicy` base for single-stage CAM-buffer schedulers."""

    name = "centralized"
    variant_of = None

    # -- per-policy hooks --------------------------------------------------
    def extra_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {}

    def policy_tick(self, cfg: SimConfig, pool, st, buf, t):
        return buf

    def score(self, cfg: SimConfig, pool, buf, is_hit, t) -> jax.Array:
        raise NotImplementedError

    def on_issue(self, cfg: SimConfig, pool, buf, do, src, t):
        return buf

    def admit_key(self, cfg: SimConfig, pool, st, buf, t):
        """(S,) admission ordering key, lowest first (default: oldest)."""
        return st["pend_birth"]

    # -- MemoryPolicy protocol ---------------------------------------------
    def configure(self, cfg: SimConfig) -> SimConfig:
        return cfg

    def init_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {**buffer_state(cfg), **self.extra_state(cfg)}

    def tick(self, cfg: SimConfig, pool, st, buf, t):
        st, buf = admit(cfg, pool, st, buf, t,
                        key=self.admit_key(cfg, pool, st, buf, t))
        buf = self.policy_tick(cfg, pool, st, buf, t)
        return st, buf

    def select(self, cfg: SimConfig, pool, st, buf, dram, t):
        """Pick + issue at most one request per channel (all channels at
        once; cross-channel state only meets in commutative scatter-adds)."""
        C = cfg.n_channels
        cidx = jnp.arange(C)
        elig, lat, is_hit = jax.vmap(
            lambda c, bank, row, valid: engine.eligibility(
                cfg, dram, c, bank, row, valid, t)
        )(cidx, buf["bank"], buf["row"], buf["valid"])          # (C, E) each
        score = self.score(cfg, pool, buf, is_hit, t)
        score = jnp.where(elig, score, -1)
        pick = jnp.argmax(score, axis=1)                        # (C,)
        at_pick = lambda a: jnp.take_along_axis(a, pick[:, None], 1)[:, 0]
        do = at_pick(score) >= 0
        src = at_pick(buf["src"])
        dram, st = engine.issue_channels(
            cfg, dram, st, do, at_pick(buf["bank"]), at_pick(buf["row"]),
            src, at_pick(buf["birth"]), at_pick(lat), at_pick(is_hit), t)
        safe = jnp.where(do, pick, 0)
        buf = dict(buf)
        clear = lambda a: a.at[cidx, safe].set(
            jnp.where(do, False, a[cidx, safe]))
        buf["valid"] = clear(buf["valid"])
        buf["marked"] = clear(buf["marked"])
        buf = self.on_issue(cfg, pool, buf, do, src, t)
        return st, buf, dram
