"""Centralized request-buffer schedulers: FR-FCFS, ATLAS, PAR-BS, TCM.

These share one structure — a per-channel CAM-style request buffer that the
policy scores every cycle — exactly the monolithic design SMS decomposes.
Scores are lexicographic integers:

    [policy bits 22+] [rank 15..20] [row-hit 14] [age 0..13]

Buffer shapes: (C, E). Admission is one request per channel per cycle
(single MC ingress port); half the entries are reserved for CPU sources
(the paper's anti-starvation provisioning, §4).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.params import SimConfig

AGE_CAP = (1 << 14) - 1
HIT_BIT = 1 << 14
RANK_SHIFT = 15
POL_BIT = 1 << 22


def buffer_state(cfg: SimConfig) -> Dict[str, Any]:
    C, E, S = cfg.n_channels, cfg.buf_entries, cfg.n_src
    z = lambda dt: jnp.zeros((C, E), dt)
    return {
        "valid": z(bool), "src": z(jnp.int32), "bank": z(jnp.int32),
        "row": z(jnp.int32), "birth": z(jnp.int32), "marked": z(bool),
        # ATLAS
        "attained": jnp.zeros((S,), jnp.float32),
        "served_epoch": jnp.zeros((S,), jnp.float32),
        # TCM
        "served_quant": jnp.zeros((S,), jnp.float32),
        "tcm_rank": jnp.zeros((S,), jnp.int32),
        "tcm_is_lat": jnp.ones((S,), bool),
        "shuffle": jnp.zeros((), jnp.int32),
        # PAR-BS
        "marked_left": jnp.zeros((S,), jnp.int32),
    }


def _rank_pos(key: jax.Array) -> jax.Array:
    """rank position of each element under ascending sort (0 = smallest)."""
    return jnp.argsort(jnp.argsort(key)).astype(jnp.int32)


def admit(cfg: SimConfig, pool, st, buf, t):
    """One admission per channel per cycle; oldest pending request wins.

    Enforces the CPU reservation: GPU sources are blocked while they hold
    >= gpu_cap entries in that channel's buffer.
    """
    S = cfg.n_src
    is_gpu_src = pool["is_gpu"]
    st = dict(st)
    buf = dict(buf)
    for c in range(cfg.n_channels):
        ch = engine.channel_of(cfg, st["pend_bank"])
        gpu_cnt = jnp.sum(buf["valid"][c] & is_gpu_src[buf["src"][c]])
        gpu_ok = gpu_cnt < cfg.gpu_cap
        cand = st["pend_valid"] & (ch == c) & (gpu_ok | ~is_gpu_src)
        has_free = ~jnp.all(buf["valid"][c])
        key = jnp.where(cand, st["pend_birth"], jnp.int32(2**30))
        s = jnp.argmin(key)
        do = cand[s] & has_free
        slot = jnp.argmin(buf["valid"][c])          # first free slot
        safe = jnp.where(do, slot, 0)
        wr = lambda a, v: a.at[c, safe].set(jnp.where(do, v, a[c, safe]))
        buf["valid"] = wr(buf["valid"], True)
        buf["src"] = wr(buf["src"], s.astype(jnp.int32))
        buf["bank"] = wr(buf["bank"], engine.bank_in_channel(cfg, st["pend_bank"][s]))
        buf["row"] = wr(buf["row"], st["pend_row"][s])
        buf["birth"] = wr(buf["birth"], st["pend_birth"][s])
        buf["marked"] = wr(buf["marked"], False)
        st["pend_valid"] = st["pend_valid"].at[s].set(
            jnp.where(do, False, st["pend_valid"][s]))
    return st, buf


# ---------------------------------------------------------------------------
# policy maintenance + scoring
# ---------------------------------------------------------------------------

def policy_tick(cfg: SimConfig, policy: str, pool, buf, t):
    """Periodic policy state updates (epochs/quanta/batch remarking)."""
    buf = dict(buf)
    S = cfg.n_src
    if policy == "atlas":
        epoch = jnp.mod(t, cfg.atlas_epoch) == 0
        att = cfg.atlas_alpha * buf["attained"] + buf["served_epoch"]
        buf["attained"] = jnp.where(epoch, att, buf["attained"])
        buf["served_epoch"] = jnp.where(epoch, 0.0, buf["served_epoch"])
    elif policy == "tcm":
        quant = jnp.mod(t, cfg.tcm_quantum) == 0
        inten = buf["served_quant"]                     # MPKC proxy
        order = _rank_pos(inten)                        # ascending intensity
        total = jnp.maximum(jnp.sum(inten), 1.0)
        # latency cluster: least-intense prefix holding <= lat_frac of BW
        sorted_i = jnp.sort(inten)
        cum = jnp.cumsum(sorted_i)
        is_lat_sorted = cum <= cfg.tcm_lat_frac * total
        new_is_lat = is_lat_sorted[order]
        # ranks: latency cluster by ascending intensity; bw cluster shuffled
        shuf = buf["shuffle"] + quant.astype(jnp.int32)
        lat_rank = order
        bw_rank = jnp.mod(order + shuf, S)
        new_rank = jnp.where(new_is_lat, lat_rank, bw_rank)
        buf["tcm_is_lat"] = jnp.where(quant, new_is_lat, buf["tcm_is_lat"])
        buf["tcm_rank"] = jnp.where(quant, new_rank, buf["tcm_rank"])
        buf["served_quant"] = jnp.where(quant, 0.0, buf["served_quant"])
        buf["shuffle"] = shuf
    elif policy == "parbs":
        # re-mark when no marked requests remain anywhere
        any_marked = jnp.any(buf["valid"] & buf["marked"])

        # per (channel, src, bank) age rank via one sort (O(E log E)):
        # sort by (group, birth); rank-in-group = index - group_start
        def remark_channel(valid, src, bank, birth):
            E = valid.shape[0]
            # int32-safe packing: group (<= 9 bits) above birth (21 bits)
            group = jnp.where(valid, src * cfg.n_banks + bank, (1 << 9) - 1)
            key = group * (1 << 21) + jnp.clip(birth, 0, (1 << 21) - 1)
            order = jnp.argsort(key)
            g_sorted = group[order]
            new_seg = jnp.concatenate([jnp.array([True]),
                                       g_sorted[1:] != g_sorted[:-1]])
            seg_start = jax.lax.cummax(
                jnp.where(new_seg, jnp.arange(E), 0))
            rank_sorted = jnp.arange(E) - seg_start
            rank = jnp.zeros((E,), jnp.int32).at[order].set(
                rank_sorted.astype(jnp.int32))
            return valid & (rank < cfg.parbs_cap)

        new_marked = jax.vmap(remark_channel)(
            buf["valid"], buf["src"], buf["bank"], buf["birth"])
        buf["marked"] = jnp.where(any_marked, buf["marked"], new_marked)
        # shortest-job ranking: total marked per src (fewest = best)
        cnt = jnp.zeros((S,), jnp.int32).at[
            jnp.where(buf["marked"] & buf["valid"], buf["src"], S)
        ].add(1, mode="drop")
        buf["marked_left"] = cnt
    return buf


def score_entries(cfg: SimConfig, policy: str, pool, buf, c: int,
                  is_hit, t):
    """int32 lexicographic score per entry of channel c (higher = better)."""
    S = cfg.n_src
    src = buf["src"][c]
    age = jnp.clip(t - buf["birth"][c], 0, AGE_CAP)
    hit = is_hit.astype(jnp.int32) * HIT_BIT
    base = hit + age
    if policy == "frfcfs":
        return base
    if policy == "atlas":
        rank = _rank_pos(buf["attained"])               # 0 = least attained
        pri = (S - rank[src]).astype(jnp.int32) << RANK_SHIFT
        return pri + base
    if policy == "parbs":
        rank = _rank_pos(buf["marked_left"])            # fewest marked = 0
        pri = (S - rank[src]).astype(jnp.int32) << RANK_SHIFT
        return buf["marked"][c].astype(jnp.int32) * POL_BIT + pri + base
    if policy == "tcm":
        pri = (S - buf["tcm_rank"][src]).astype(jnp.int32) << RANK_SHIFT
        return buf["tcm_is_lat"][src].astype(jnp.int32) * POL_BIT + pri + base
    raise ValueError(policy)


def schedule_and_issue(cfg: SimConfig, policy: str, pool, st, buf, dram, t):
    """Pick + issue at most one request per channel."""
    for c in range(cfg.n_channels):
        elig, lat, is_hit = engine.eligibility(
            cfg, dram, c, buf["bank"][c], buf["row"][c], buf["valid"][c], t)
        score = score_entries(cfg, policy, pool, buf, c, is_hit, t)
        score = jnp.where(elig, score, -1)
        pick = jnp.argmax(score)
        do = score[pick] >= 0
        src = buf["src"][c, pick]
        dram, st = engine.issue(cfg, dram, st, c, do, buf["bank"][c, pick],
                                buf["row"][c, pick], src,
                                buf["birth"][c, pick], lat[pick],
                                is_hit[pick], t)
        safe = jnp.where(do, pick, 0)
        buf = dict(buf)
        buf["valid"] = buf["valid"].at[c, safe].set(
            jnp.where(do, False, buf["valid"][c, safe]))
        buf["marked"] = buf["marked"].at[c, safe].set(
            jnp.where(do, False, buf["marked"][c, safe]))
        inc = jnp.where(do, 1.0, 0.0)
        ssafe = jnp.where(do, src, 0)
        upd = lambda a: a.at[ssafe].add(inc)
        buf["served_epoch"] = upd(buf["served_epoch"])
        buf["served_quant"] = upd(buf["served_quant"])
    return st, buf, dram


def make_step(cfg: SimConfig, policy: str):
    """One simulator cycle for a centralized-buffer policy."""

    def step(carry, t):
        st, buf, dram = carry
        pool, active = st["_pool"], st["_active"]
        st, dram = engine.completions_tick(st, dram, t)
        st = engine.deadline_tick(cfg, pool, st, t)
        st = engine.source_tick(cfg, pool, st, active, t)
        st, buf = admit(cfg, pool, st, buf, t)
        buf = policy_tick(cfg, policy, pool, buf, t)
        st, buf, dram = schedule_and_issue(cfg, policy, pool, st, buf, dram, t)
        return (st, buf, dram), None

    return step
