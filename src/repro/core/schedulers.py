"""Centralized request-buffer substrate for `MemoryPolicy` implementations.

The FR-FCFS family (FR-FCFS, ATLAS, PAR-BS, TCM, BLISS, SQUASH-prio, ...)
shares one structure — a per-channel CAM-style request buffer scored every
cycle — exactly the monolithic design SMS decomposes. This module provides
that substrate as `CentralizedPolicy`, a base class for the protocol in
`repro.core.policy`: subclasses (one module each under
`repro.core.policies/`) override

    extra_state(cfg)                       policy-private state arrays
    boundary_pred(cfg, pool, st, buf, t)   scalar bool: run boundary_tick?
                                           (None = policy has no boundary)
    boundary_tick(cfg, pool, st, buf, t)   epoch/quantum/batch maintenance,
                                           executed under `lax.cond`
    policy_tick(cfg, pool, st, buf, t)     cheap per-cycle maintenance
    score(cfg, pool, buf, is_hit, t)       (C, E) int32 lexicographic score
    on_admit(cfg, pool, st, buf, do, slot, src, t)   per-admission hook
    on_issue(cfg, pool, buf, do, pick, src, t)       per-issue hook (buf is
                                                     the PRE-clear buffer)

Hot-loop contract (see ROADMAP "hot-loop rules"): anything that sorts or
ranks belongs in `boundary_tick`. A predicate that depends only on the
scan's scalar cycle counter `t` stays unbatched under `vmap`, so the cond
branch genuinely executes once per epoch; a data-dependent predicate
degrades to `select` under `vmap` but still keeps the sort out of the
unbatched per-cycle jaxpr. The default `score` adds a cached per-source
priority (`buf["pri_src"]`, computed by `boundary_tick`) to the FR-FCFS
base score, so no subclass ranks in `score`.

Scores are lexicographic integers:

    [policy bits 22+] [rank 15..20] [row-hit 14] [age 0..13]

Buffer shapes: (C, E). Admission is one request per channel per cycle
(single MC ingress port); half the entries are reserved for CPU sources
(the paper's anti-starvation provisioning, §4): GPU occupancy is tracked
by the incrementally-maintained `gpu_occ` counter (admit +1, issue -1)
instead of an O(C·E) reduction each cycle. Admission and issue are
expressed as whole-(C, ...) array ops — channels never appear as a Python
loop, so trace size is independent of `n_channels`.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import energy, engine, params, telemetry, validate
from repro.core.params import SimConfig

AGE_CAP = (1 << 14) - 1
HIT_BIT = 1 << 14
RANK_SHIFT = 15
POL_BIT = 1 << 22


def buffer_state(cfg: SimConfig) -> Dict[str, Any]:
    """The shared CAM buffer; policy-private arrays live in extra_state.

    `gpu_occ` mirrors `sum(valid & is_gpu_src[src])` per channel — admit
    increments it, issue decrements it — so the CPU-reservation check never
    re-scans the buffer.
    """
    C, E = cfg.n_channels, cfg.buf_entries
    z = lambda dt: jnp.zeros((C, E), dt)
    return {
        "valid": z(bool), "src": z(jnp.int32), "bank": z(jnp.int32),
        "row": z(jnp.int32), "birth": z(jnp.int32), "marked": z(bool),
        "gpu_occ": jnp.zeros((C,), jnp.int32),
    }


def rank_pos(key: jax.Array) -> jax.Array:
    """rank position of each element under ascending sort (0 = smallest)."""
    return jnp.argsort(jnp.argsort(key)).astype(jnp.int32)


def base_score(cfg: SimConfig, buf, is_hit, t) -> jax.Array:
    """FR-FCFS core: row hit above age. (C, E) int32."""
    age = jnp.clip(t - buf["birth"], 0, AGE_CAP)
    return is_hit.astype(jnp.int32) * HIT_BIT + age


def admit(cfg: SimConfig, pool, st, buf, t, key=None):
    """One admission per channel per cycle; lowest-key pending request wins
    (default key: birth, i.e. oldest first).

    Enforces the CPU reservation: GPU sources are blocked while they hold
    >= gpu_cap entries in that channel's buffer (tracked by the `gpu_occ`
    counter). Sources map to exactly one channel, so all channels admit
    independently in one batched op.

    Returns (st, buf, do, slot, src): per-channel admission outcome for
    `on_admit` hooks.
    """
    S, C = cfg.n_src, cfg.n_channels
    is_gpu_src = pool["is_gpu"]
    st = dict(st)
    buf = dict(buf)
    cidx = jnp.arange(C)
    ch = engine.channel_of(cfg, st["pend_bank"])                # (S,)
    gpu_ok = buf["gpu_occ"] < cfg.gpu_cap
    cand = st["pend_valid"][None, :] & (ch[None, :] == cidx[:, None]) \
        & (gpu_ok[:, None] | ~is_gpu_src[None, :])              # (C, S)
    has_free = ~jnp.all(buf["valid"], axis=1)                   # (C,)
    key = st["pend_birth"] if key is None else key
    key = jnp.where(cand, key[None, :], jnp.int32(2**30))
    s = jnp.argmin(key, axis=1)                                 # (C,)
    do = cand[cidx, s] & has_free
    slot = jnp.argmin(buf["valid"], axis=1)                     # first free
    wr = lambda a, v: engine.masked_set(a, slot, v, do)
    buf["valid"] = wr(buf["valid"], True)
    buf["src"] = wr(buf["src"], s.astype(jnp.int32))
    buf["bank"] = wr(buf["bank"], engine.bank_in_channel(cfg,
                                                         st["pend_bank"][s]))
    buf["row"] = wr(buf["row"], st["pend_row"][s])
    buf["birth"] = wr(buf["birth"], st["pend_birth"][s])
    buf["marked"] = wr(buf["marked"], False)
    buf["gpu_occ"] = buf["gpu_occ"] + \
        (do & is_gpu_src[s]).astype(jnp.int32)
    taken = jnp.any((jnp.arange(S) == s[:, None]) & do[:, None], axis=0)
    st["pend_valid"] = st["pend_valid"] & ~taken
    return st, buf, do, slot, s.astype(jnp.int32)


class CentralizedPolicy:
    """`MemoryPolicy` base for single-stage CAM-buffer schedulers.

    The per-cycle step is split in two: `policy_tick` runs every cycle and
    must stay cheap (no sorts, no O(C·E) reductions for incrementally
    maintainable state); `boundary_tick` holds the epoch/quantum/batch
    maintenance — ranking sorts included — and executes under `lax.cond`
    gated on `boundary_pred`.
    """

    name = "centralized"
    variant_of = None

    # keys `boundary_tick` may WRITE. The cond's operands/outputs are
    # restricted to these (everything else is read through the closure), so
    # the per-cycle step never copies or selects untouched (C, E) arrays
    # through the conditional. Keep this to the small (S,)-shaped state.
    boundary_keys: tuple = ()

    # -- cross-policy stacking contract (see `make_stacked_step`) -----------
    # A stackable policy agrees to run with its state padded to the family
    # union schema (extra keys from sibling policies present but zero) and
    # with `configure` leaving cfg untouched. Opt out with `stackable =
    # False` for state that cannot be padded (or schema-colliding keys).
    stackable: bool = True
    # buf keys the tick-side hooks (on_admit/pre_tick/boundary_tick/
    # policy_tick) may WRITE. None = `boundary_keys`. The stacked step
    # re-stacks only the union of these across the family; an undeclared
    # write is silently dropped on the stacked path (and caught by the
    # golden-digest equivalence test).
    stacked_tick_keys: tuple = None
    # buf keys `on_issue` may WRITE (default: none).
    stacked_issue_keys: tuple = ()

    # -- per-policy hooks --------------------------------------------------
    def extra_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {}

    def pre_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Per-cycle maintenance that must run BEFORE the boundary gate
        (state that `boundary_pred`/`boundary_tick` read). Sort-free."""
        return buf

    def boundary_pred(self, cfg: SimConfig, pool, st, buf, t):
        """Scalar bool gating `boundary_tick`; None = no boundary work.

        Predicates that depend only on `t` stay unbatched under `vmap`, so
        the gated branch truly runs once per epoch.
        """
        return None

    def boundary_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Cond-gated maintenance: rank recomputes, shuffles. May read any
        state but only write `boundary_keys`."""
        return buf

    def policy_tick(self, cfg: SimConfig, pool, st, buf, t):
        """Unconditional per-cycle maintenance; keep it sort-free."""
        return buf

    def score(self, cfg: SimConfig, pool, buf, is_hit, t) -> jax.Array:
        """Default: cached per-source priority + FR-FCFS base score."""
        s = base_score(cfg, buf, is_hit, t)
        if "pri_src" in buf:
            s = buf["pri_src"][buf["src"]] + s
        return s

    def on_admit(self, cfg: SimConfig, pool, st, buf, do, slot, src, t):
        """Per-admission accounting ((C,) vectors, after the buffer write)."""
        return buf

    def on_issue(self, cfg: SimConfig, pool, buf, do, pick, src, t):
        """Per-issue accounting. `buf` is PRE-clear: entry `pick` still
        holds the issued request's fields."""
        return buf

    def admit_key(self, cfg: SimConfig, pool, st, buf, t):
        """(S,) admission ordering key, lowest first (default: oldest)."""
        return st["pend_birth"]

    def next_boundary(self, cfg: SimConfig, pool, st, buf, t):
        """Scalar: earliest cycle > t at which `boundary_pred` could fire or
        any other per-cycle policy state could change in a way the generic
        witnesses don't cover (e.g. a t-dependent urgency flip). None = no
        boundary machinery. Early is safe, late is a correctness bug (see
        ROADMAP "Variable-step driver contract")."""
        return None

    # -- variable-step driver witness (see `policy.make_skip_step`) ---------
    def next_event(self, cfg: SimConfig, pool, st, buf, dram, t):
        """Earliest cycle > t at which this policy's half of the cycle could
        do anything: admit a pending request, issue a buffered one, or run
        boundary maintenance. Evaluated on post-cycle-t state."""
        te = next_admission(cfg, pool, st, buf, t)
        te = jnp.minimum(te, next_issue_ready(cfg, buf, dram, t))
        nb = self.next_boundary(cfg, pool, st, buf, t)
        if nb is not None:
            te = jnp.minimum(te, nb)
        return te

    # -- invariant-sanitizer hooks (repro.core.validate; measurement-only,
    # traced only when cfg.validate_enabled — see ROADMAP "Validation &
    # fault-injection contract") ------------------------------------------
    def queued_requests(self, cfg: SimConfig, buf):
        """Requests held in policy structures (total-flow conservation)."""
        return jnp.sum(buf["valid"].astype(jnp.int32))

    def check_invariants(self, cfg: SimConfig, pool, st, buf, t):
        """Count of violated buffer invariants: the `gpu_occ` mirror counter
        matches a recount of GPU-held entries, occupancy stays within
        [0, E], and marks only sit on valid entries. Subclasses extend with
        their own mirror-counter recounts (e.g. PAR-BS `msub`/`grank`)."""
        occ = jnp.sum((buf["valid"] & pool["is_gpu"][buf["src"]])
                      .astype(jnp.int32), axis=1)
        bad = jnp.sum((occ != buf["gpu_occ"]).astype(jnp.int32))
        bad += jnp.sum(((buf["gpu_occ"] < 0) |
                        (buf["gpu_occ"] > cfg.buf_entries)).astype(jnp.int32))
        bad += jnp.sum((buf["marked"] & ~buf["valid"]).astype(jnp.int32))
        return bad

    def audit_skip(self, cfg: SimConfig, pool, st, buf, dram, t, t_new):
        """Would-fire lateness predicates for a jumped span: independent
        inline re-derivations of admission/issue readiness (never the
        witness formulas themselves), evaluated at the last skipped cycle
        `u` — valid because readiness is monotone in t over frozen span
        state. `next_boundary` is safe to reuse: it was evaluated at t by
        the driver, so `nb < t_new` can only mean the driver ignored it."""
        u = t_new - 1
        skipped = t_new - t > 1
        ch = engine.channel_of(cfg, st["pend_bank"])
        gpu_ok = buf["gpu_occ"] < cfg.gpu_cap
        has_free = ~jnp.all(buf["valid"], axis=1)
        adm = jnp.any(st["pend_valid"] & has_free[ch] &
                      (gpu_ok[ch] | ~pool["is_gpu"]))
        elig, _, _ = eligibility_grid(cfg, buf, dram, u)
        out = {"late_admission": (skipped & adm).astype(jnp.int32),
               "late_issue": (skipped & jnp.any(elig)).astype(jnp.int32)}
        nb = self.next_boundary(cfg, pool, st, buf, t)
        if nb is not None:
            out["late_boundary"] = (skipped & (nb < t_new)).astype(jnp.int32)
        return out

    # -- MemoryPolicy protocol ---------------------------------------------
    def configure(self, cfg: SimConfig) -> SimConfig:
        return cfg

    def init_state(self, cfg: SimConfig) -> Dict[str, Any]:
        return {**buffer_state(cfg), **self.extra_state(cfg)}

    def tick_hooks(self, cfg: SimConfig, pool, st, buf, do, slot, src, t):
        """Everything policy-specific between admission and selection:
        per-admission accounting, cheap maintenance, the cond-gated boundary
        work. The stacked step dispatches here per policy slice."""
        buf = self.on_admit(cfg, pool, st, buf, do, slot, src, t)
        buf = self.pre_tick(cfg, pool, st, buf, t)
        pred = self.boundary_pred(cfg, pool, st, buf, t)
        if pred is not None:
            keys = self.boundary_keys

            def run(sub):
                new = self.boundary_tick(cfg, pool, st, {**buf, **sub}, t)
                return {k: new[k] for k in keys}

            sub = jax.lax.cond(pred, run, lambda s: s,
                               {k: buf[k] for k in keys})
            buf = {**buf, **sub}
        buf = self.policy_tick(cfg, pool, st, buf, t)
        return buf

    def tick(self, cfg: SimConfig, pool, st, buf, t):
        st, buf, do, slot, src = admit(
            cfg, pool, st, buf, t,
            key=self.admit_key(cfg, pool, st, buf, t))
        buf = self.tick_hooks(cfg, pool, st, buf, do, slot, src, t)
        return st, buf

    def select(self, cfg: SimConfig, pool, st, buf, dram, t):
        """Pick + issue at most one request per channel (all channels at
        once; cross-channel state only meets in commutative scatter-adds)."""
        elig, lat, is_hit = eligibility_grid(cfg, buf, dram, t)
        score = self.score(cfg, pool, buf, is_hit, t)
        score = jnp.where(elig, score, -1)
        st, dram, do, pick, src = issue_picked(cfg, st, buf, dram, score,
                                               lat, is_hit, t)
        buf = self.on_issue(cfg, pool, buf, do, pick, src, t)
        buf = clear_picked(cfg, pool, buf, do, pick, src)
        return st, buf, dram


def eligibility_grid(cfg: SimConfig, buf, dram, t):
    """Per-entry issue legality for every channel: (C, E) elig/lat/is_hit."""
    cidx = jnp.arange(cfg.n_channels)
    return jax.vmap(
        lambda c, bank, row, valid: engine.eligibility(
            cfg, dram, c, bank, row, valid, t)
    )(cidx, buf["bank"], buf["row"], buf["valid"])


def issue_picked(cfg: SimConfig, st, buf, dram, score, lat, is_hit, t):
    """argmax the masked score per channel and commit the issue to DRAM.

    Returns (st, dram, do, pick, src); `buf` is untouched (still pre-clear)
    so `on_issue` hooks can read the issued entry's fields.
    """
    pick = jnp.argmax(score, axis=1)                            # (C,)
    at_pick = lambda a: jnp.take_along_axis(a, pick[:, None], 1)[:, 0]
    do = at_pick(score) >= 0
    src = at_pick(buf["src"])
    dram, st = engine.issue_channels(
        cfg, dram, st, do, at_pick(buf["bank"]), at_pick(buf["row"]),
        src, at_pick(buf["birth"]), at_pick(lat), at_pick(is_hit), t)
    return st, dram, do, pick, src


def clear_picked(cfg: SimConfig, pool, buf, do, pick, src):
    """Free the issued entries and settle the GPU-occupancy counter."""
    buf = dict(buf)
    clear = lambda a: engine.masked_set(a, pick, False, do)
    buf["valid"] = clear(buf["valid"])
    buf["marked"] = clear(buf["marked"])
    buf["gpu_occ"] = buf["gpu_occ"] - \
        (do & pool["is_gpu"][src]).astype(jnp.int32)
    return buf


# ---------------------------------------------------------------------------
# variable-step witnesses for the centralized substrate (conservative-early;
# see ROADMAP "Variable-step driver contract"). Both are evaluated on
# post-cycle state; any state they read is frozen until one of the family of
# witnesses fires, which is what makes the returned times trustworthy.
# ---------------------------------------------------------------------------

def next_admission(cfg: SimConfig, pool, st, buf, t):
    """t+1 if any pending request could be admitted next cycle, else INF.

    Admissibility can only change via events other witnesses already cover
    (a new pending request = source event; a freed slot or GPU-occupancy
    drop = issue event), so a currently-blocked pending register stays
    blocked for the whole span."""
    ch = engine.channel_of(cfg, st["pend_bank"])                 # (S,)
    gpu_ok = buf["gpu_occ"] < cfg.gpu_cap                        # (C,)
    has_free = ~jnp.all(buf["valid"], axis=1)                    # (C,)
    ok = st["pend_valid"] & has_free[ch] & \
        (gpu_ok[ch] | ~pool["is_gpu"])
    return jnp.where(jnp.any(ok), t + 1, jnp.int32(engine.INF_T))


def next_issue_ready(cfg: SimConfig, buf, dram, t):
    """Earliest cycle > t at which any buffered entry becomes issue-eligible.

    Inverts `engine.eligibility`'s three timing gates per entry — bank
    ready, tFAW window, bus ready — whose inputs (bank_free/act_ring/
    bus_free/open_row) are all frozen while no issue lands. Every policy's
    score is non-negative for eligible entries, so first-eligibility time
    is exactly first-issue time (and if a future policy ever suppressed an
    eligible entry, an early witness merely processes a no-op cycle)."""
    tm = cfg.timing
    take = lambda a: jnp.take_along_axis(a, buf["bank"], 1)      # (C, E)
    openv = take(dram["open_valid"])
    is_hit = openv & (take(dram["open_row"]) == buf["row"])
    lat = jnp.where(is_hit, tm.lat_hit,
                    jnp.where(openv, tm.lat_conflict, tm.lat_closed)
                    ).astype(jnp.int32)
    faw_ready = jnp.min(dram["act_ring"], axis=1)[:, None] + tm.t_faw
    tau = jnp.maximum(take(dram["bank_free"]),
                      jnp.where(is_hit, engine.NEG_T, faw_ready))
    tau = jnp.maximum(tau, dram["bus_free"][:, None] - lat)
    tau = jnp.maximum(tau, t + 1)
    return jnp.min(jnp.where(buf["valid"], tau, jnp.int32(engine.INF_T)))


# ---------------------------------------------------------------------------
# Stacked cross-policy execution: the whole CentralizedPolicy family as ONE
# scan step / ONE XLA program.
#
# The centralized policies share the buffer layout and the engine half of
# the cycle; they differ only in the hook bodies. So: pad every policy's
# state to the union schema, stack the states on a leading P axis, and per
# cycle run the policy-independent work (source/completion ticks, admission,
# eligibility, issue, clear) ONCE, vmapped over the policy axis, while the
# policy-specific hooks dispatch on the per-policy index over slices of the
# stacked state.
#
# Why the dispatch is per-slice (trace-time index) and not a traced
# `lax.switch` under `vmap`: with the policy index batched, jax's cond/switch
# batching rule inlines ALL branches and select_n's the results — including
# dissolving each branch's *nested* boundary `lax.cond` even when its
# predicate depends only on the scalar cycle counter (measured on the pinned
# jax 0.4.37). That would run every policy's ranking sort every cycle for
# every slice: O(P^2) hook work and a direct violation of hot-loop rule 1.
# Dispatching on the concrete per-policy index keeps exactly one hook body
# per slice in the trace and keeps every t-only boundary predicate unbatched
# (a genuine cond), while the whole family still compiles as one program.
# ---------------------------------------------------------------------------


def stacked_union_state(cfg: SimConfig, pols) -> list:
    """Per-policy init states padded to the family union schema.

    Returns a list of dicts (same keys, same shapes/dtypes) ready to stack
    on a leading P axis. A key claimed by two policies with different
    shape/dtype is a schema collision and refuses to stack.
    """
    states = [p.init_state(cfg) for p in pols]
    union: Dict[str, Any] = {}
    owner: Dict[str, str] = {}
    for p, s in zip(pols, states):
        for k, v in s.items():
            if k in union:
                if union[k].shape != v.shape or union[k].dtype != v.dtype:
                    raise ValueError(
                        f"stacked schema collision on {k!r}: "
                        f"{owner[k]} has {union[k].shape}/{union[k].dtype}, "
                        f"{p.name} has {v.shape}/{v.dtype}")
            else:
                union[k] = jnp.zeros(v.shape, v.dtype)
                owner[k] = p.name
    return [{**union, **s} for s in states]


def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _slice_tree(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def make_stacked_step(cfg: SimConfig, pols, pool, active, cfgs=None,
                      knobs=None):
    """One simulator cycle for P stacked centralized policies.

    The carry is the usual (st, buf, dram) triple with every leaf carrying a
    leading P axis (buf padded to the union schema). Policy-independent work
    runs once, vmapped over P; `admit_key`/`tick_hooks`/`score`/`on_issue`
    dispatch per policy slice, and only the union of each policy family's
    declared write-sets is re-stacked — untouched padding rides through the
    carry unchanged.

    Knob-grid extension (both default to the legacy trace when None):
    `cfgs` supplies a per-slice config view — e.g. `params.bind`ed
    BoundConfigs carrying per-slice period overrides and knob slices — for
    the per-slice hook dispatch; `knobs` is the variant-stacked Knobs
    pytree, vmapped into the two pieces of shared engine work that read
    value knobs (admission's gpu_cap, the power-down idle threshold).
    """
    P = len(pols)
    cfgs = list(cfgs) if cfgs is not None else [cfg] * P
    tick_union = sorted(set().union(*(
        p.stacked_tick_keys if p.stacked_tick_keys is not None
        else p.boundary_keys for p in pols)))
    issue_union = sorted(set().union(*(p.stacked_issue_keys for p in pols)))
    vP = jax.vmap

    def step(carry, t):
        st, buf, dram = carry
        if cfg.telemetry_enabled:
            snap = vP(telemetry.snapshot)(st, buf, dram)
        st, dram = vP(lambda s, d: engine.completions_tick(s, d, t)
                      )(st, dram)
        if knobs is None:
            dram = vP(lambda d: energy.background_tick(cfg, d, t))(dram)
        else:
            dram = vP(lambda d, kn: energy.background_tick(
                params.bind(cfg, kn), d, t))(dram, knobs)
        st = vP(lambda s: engine.deadline_tick(cfg, pool, s, t))(st)
        st = vP(lambda s: engine.source_tick(cfg, pool, s, active, t))(st)
        # admission: policy-ordered key per slice, one merged admit
        key = jnp.stack([
            p.admit_key(cfgs[i], pool, _slice_tree(st, i),
                        _slice_tree(buf, i), t)
            for i, p in enumerate(pols)])
        if knobs is None:
            st, buf, do, slot, src = vP(
                lambda s, b, k: admit(cfg, pool, s, b, t, key=k)
                )(st, buf, key)
        else:
            st, buf, do, slot, src = vP(
                lambda s, b, k, kn: admit(params.bind(cfg, kn), pool, s, b,
                                          t, key=k))(st, buf, key, knobs)
        new = [p.tick_hooks(cfgs[i], pool, _slice_tree(st, i),
                            _slice_tree(buf, i), do[i], slot[i], src[i], t)
               for i, p in enumerate(pols)]
        buf = {**buf, **{k: jnp.stack([n[k] for n in new])
                         for k in tick_union}}
        # selection: merged eligibility/issue, per-slice score + on_issue
        elig, lat, is_hit = vP(
            lambda b, d: eligibility_grid(cfg, b, d, t))(buf, dram)
        score = jnp.stack([
            p.score(cfgs[i], pool, _slice_tree(buf, i), is_hit[i], t)
            for i, p in enumerate(pols)])
        score = jnp.where(elig, score, -1)
        st, dram, do, pick, src = vP(
            lambda s, b, d, sc, la, hi: issue_picked(cfg, s, b, d, sc, la,
                                                     hi, t)
        )(st, buf, dram, score, lat, is_hit)
        if issue_union:
            new = [p.on_issue(cfgs[i], pool, _slice_tree(buf, i), do[i],
                              pick[i], src[i], t)
                   for i, p in enumerate(pols)]
            buf = {**buf, **{k: jnp.stack([n[k] for n in new])
                             for k in issue_union}}
        buf = vP(lambda b, d, pk, sr: clear_picked(cfg, pool, b, d, pk, sr)
                 )(buf, do, pick, src)
        if cfg.telemetry_enabled:
            # policy-independent accrual (no value knobs read): vmap over
            # P like the engine work rather than dispatching per slice
            dram = vP(lambda sn, s, b, d: telemetry.tick_accrue(
                cfg, pool, sn, s, b, d, t))(snap, st, buf, dram)
        if cfg.validate_enabled:
            # conservation laws dispatch per slice like the other hooks
            # (policy invariants differ per policy object)
            vio = jnp.stack([
                _slice_tree(dram, i)["viol"] + validate.tick_counts(
                    cfgs[i], pool, p, _slice_tree(st, i),
                    _slice_tree(buf, i), _slice_tree(dram, i), t)
                for i, p in enumerate(pols)])
            dram = {**dram, "viol": vio}
        return (st, buf, dram), None

    return step


def make_stacked_skip_step(cfg: SimConfig, pols, pool, active, cfgs=None,
                           knobs=None):
    """Variable-step body for the stacked family (see `policy.make_skip_step`
    for the single-policy contract).

    All P slices share one cycle counter, so a span ends at the MINIMUM
    witness across slices — every slice is processed at every event any
    slice has, which keeps each slice bit-identical to its ticked run (extra
    processed cycles are no-ops by the conservative-early rule) at the cost
    of a lower skip ratio than per-policy execution. The shared witnesses
    (engine sources/completions, admission, issue readiness) vmap over P —
    computing them per slice would multiply the dominant witness cost by
    the family size; only the cheap policy-specific `next_boundary`
    dispatches per slice at trace time like the other hooks.
    """
    if not all(hasattr(p, "next_event") for p in pols):
        return None
    step = make_stacked_step(cfg, pols, pool, active, cfgs=cfgs, knobs=knobs)
    cfgs = list(cfgs) if cfgs is not None else [cfg] * len(pols)
    vP = jax.vmap

    def skip_body(carry, t, t_end):
        carry, _ = step(carry, t)
        st, buf, dram = carry
        te = jnp.min(vP(lambda s: engine.next_source_event(
            cfg, pool, s, active, t))(st))
        te = jnp.minimum(te, jnp.min(vP(
            lambda d: engine.next_completion(d, t))(dram)))
        if knobs is None:
            te = jnp.minimum(te, jnp.min(vP(
                lambda s, b: next_admission(cfg, pool, s, b, t))(st, buf)))
        else:
            # admission readiness reads gpu_cap, a value knob — thread the
            # per-slice knob point through the vmapped witness
            te = jnp.minimum(te, jnp.min(vP(
                lambda s, b, kn: next_admission(params.bind(cfg, kn), pool,
                                                s, b, t))(st, buf, knobs)))
        te = jnp.minimum(te, jnp.min(vP(
            lambda b, d: next_issue_ready(cfg, b, d, t))(buf, dram)))
        for i, p in enumerate(pols):
            nb = p.next_boundary(cfgs[i], pool, _slice_tree(st, i),
                                 _slice_tree(buf, i), t)
            if nb is not None:
                te = jnp.minimum(te, nb)
        t_new = jnp.minimum(te, t_end)
        k = t_new - t - 1
        st = vP(lambda s: engine.skip_sources(cfg, pool, s, active, k))(st)
        if cfg.telemetry_enabled:
            # before energy.skip_accrue (pre-span pd_down); the power-down
            # entry threshold is a value knob, so bind per slice on grids
            if knobs is None:
                dram = vP(lambda s, d: telemetry.skip_accrue(
                    cfg, pool, s, d, t, t_new))(st, dram)
            else:
                dram = vP(lambda s, d, kn: telemetry.skip_accrue(
                    params.bind(cfg, kn), pool, s, d, t, t_new)
                    )(st, dram, knobs)
        if knobs is None:
            dram = vP(lambda d: energy.skip_accrue(cfg, d, t, t_new))(dram)
        else:
            dram = vP(lambda d, kn: energy.skip_accrue(
                params.bind(cfg, kn), d, t, t_new))(dram, knobs)
        if cfg.validate_enabled:
            vio = jnp.stack([
                _slice_tree(dram, i)["viol"] + validate.span_counts(
                    cfgs[i], pool, p, _slice_tree(st, i),
                    _slice_tree(buf, i), _slice_tree(dram, i), active,
                    t, t_new)
                for i, p in enumerate(pols)])
            dram = {**dram, "viol": vio}
        return (st, buf, dram), t_new

    return skip_body
