"""Staged Memory Scheduler — the paper's contribution (§2).

Three decoupled stages, all simple FIFOs:
  1. per-source batch formation FIFOs (C, S, F): consecutive same-(bank,row)
     requests form a batch; ready on row-change / age threshold / full FIFO;
  2. batch scheduler: picks a ready batch — SJF (fewest in-flight across all
     stages) with probability p, round-robin with 1-p — then drains it one
     request/cycle into stage 3;
  3. DRAM command scheduler (DCS): per-bank FIFOs (C, B, D); only FIFO heads
     issue; DRAM timing legality enforced; round-robin across banks.

Unlike the centralized schedulers there is no CAM scan: every structure is a
head/length circular FIFO — which is exactly the power/area claim §5.2
audits.

These stage functions are the implementation behind the registered "sms" /
"sms_dash" `MemoryPolicy` objects (see `repro.core.policies.sms`): stages 1+2
form the policy's `tick`, stage 3 its `select`. Every stage is a whole-array
op over all channels at once — no Python channel loop — so trace size and
compile time are independent of `n_channels`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.params import SimConfig


def sms_state(cfg: SimConfig) -> Dict[str, Any]:
    C, S, F = cfg.n_channels, cfg.n_src, cfg.fifo_size
    B, D = cfg.n_banks, cfg.dcs_size
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    return {
        # stage 1: per-source FIFOs
        "f_row": zi(C, S, F), "f_bank": zi(C, S, F), "f_birth": zi(C, S, F),
        "f_head": zi(C, S), "f_len": zi(C, S),
        # stage 2: batch scheduler
        "drain_src": jnp.full((C,), -1, jnp.int32),
        "drain_left": zi(C),
        "rr_ptr": zi(C),
        "rng2": jnp.arange(1, C + 1, dtype=jnp.uint32) * jnp.uint32(40503),
        # stage 3: per-bank DCS FIFOs
        "d_row": zi(C, B, D), "d_src": zi(C, B, D), "d_birth": zi(C, B, D),
        "d_head": zi(C, B), "d_len": zi(C, B), "rr_bank": zi(C),
    }


def _fifo_view(rows, banks, births, head, length, F):
    """Return FIFO contents in age order + in-range mask. (..., F) arrays."""
    idx = (head[..., None] + jnp.arange(F)) % F
    take = lambda a: jnp.take_along_axis(a, idx, axis=-1)
    in_range = jnp.arange(F) < length[..., None]
    return take(rows), take(banks), take(births), in_range


def batch_info(cfg: SimConfig, sms: Dict[str, Any], t):
    """(C,S) arrays: batch_len (front same-(bank,row) run) and readiness."""
    F = cfg.fifo_size
    rows_o, banks_o, births_o, in_r = _fifo_view(
        sms["f_row"], sms["f_bank"], sms["f_birth"],
        sms["f_head"], sms["f_len"], F)
    eq = (rows_o == rows_o[..., :1]) & (banks_o == banks_o[..., :1]) & in_r
    run = jnp.cumprod(eq.astype(jnp.int32), axis=-1)
    batch_len = jnp.sum(run, axis=-1)                       # (C,S)
    nonempty = sms["f_len"] > 0
    row_changed = batch_len < sms["f_len"]
    aged = nonempty & (t - births_o[..., 0] >= cfg.batch_age_cap)
    full = sms["f_len"] >= F
    ready = nonempty & (row_changed | aged | full)
    return batch_len, ready


def stage1_admit(cfg: SimConfig, st, sms, t):
    """Decentralized admission: every source pushes into its own FIFO."""
    S, F = cfg.n_src, cfg.fifo_size
    st = dict(st)
    sms = dict(sms)
    ch = engine.channel_of(cfg, st["pend_bank"])            # (S,)
    room = sms["f_len"][ch, jnp.arange(S)] < F
    do = st["pend_valid"] & room
    slot = (sms["f_head"][ch, jnp.arange(S)] +
            sms["f_len"][ch, jnp.arange(S)]) % F
    cs, ss = jnp.where(do, ch, 0), jnp.arange(S)
    slot_s = jnp.where(do, slot, 0)
    wr = lambda a, v: a.at[cs, ss, slot_s].set(
        jnp.where(do, v, a[cs, ss, slot_s]))
    sms["f_row"] = wr(sms["f_row"], st["pend_row"])
    sms["f_bank"] = wr(sms["f_bank"],
                       engine.bank_in_channel(cfg, st["pend_bank"]))
    sms["f_birth"] = wr(sms["f_birth"], st["pend_birth"])
    sms["f_len"] = sms["f_len"].at[cs, ss].add(jnp.where(do, 1, 0))
    st["pend_valid"] = st["pend_valid"] & ~do
    return st, sms


def stage2_drain(cfg: SimConfig, st, sms, t):
    """Pick ready batches (SJF w.p. p / RR w.p. 1-p) and drain 1 req/cycle."""
    C, S, F = cfg.n_channels, cfg.n_src, cfg.fifo_size
    B, D = cfg.n_banks, cfg.dcs_size
    sms = dict(sms)
    batch_len, ready = batch_info(cfg, sms, t)

    # --- pick a new batch on idle channels ---
    idle = sms["drain_left"] <= 0
    rng2, u = engine.lcg_step(sms["rng2"])
    sms["rng2"] = rng2
    use_sjf = u < cfg.sjf_prob                              # (C,)
    inflight = (st["emitted"] - st["completed"]).astype(jnp.int32)  # (S,)
    sjf_key = jnp.where(ready, inflight[None, :], 1 << 28)  # (C,S)
    sjf_pick = jnp.argmin(sjf_key, axis=-1)
    rr_off = (jnp.arange(S)[None, :] - sms["rr_ptr"][:, None]) % S
    rr_key = jnp.where(ready, rr_off, 1 << 28)
    rr_pick = jnp.argmin(rr_key, axis=-1)
    pick = jnp.where(use_sjf, sjf_pick, rr_pick)
    if cfg.dash:
        # SMS-DASH (paper §7 / Usui et al.): a deadline source whose frame
        # slack is below its estimated remaining service time preempts the
        # SJF/RR choice; least-slack-first among urgent ready batches.
        pool = st["_pool"]
        has_dl = pool["dl_period"] > 0
        remaining = jnp.maximum(pool["dl_reqs"] - st["period_done"], 0)
        time_left = pool["dl_period"] - jnp.mod(
            t, jnp.maximum(pool["dl_period"], 1))
        slack = time_left.astype(jnp.float32) - \
            remaining.astype(jnp.float32) * cfg.dash_svc_est
        urgent = has_dl & (slack < 0.0) & (remaining > 0)
        urgent_ready = ready & urgent[None, :]
        u_key = jnp.where(urgent_ready, slack[None, :], jnp.float32(1e30))
        u_pick = jnp.argmin(u_key, axis=-1)
        any_urgent = jnp.any(urgent_ready, axis=-1)
        pick = jnp.where(any_urgent, u_pick, pick)
        use_sjf = use_sjf | any_urgent          # don't advance rr on preempt
    any_ready = jnp.any(ready, axis=-1)
    start = idle & any_ready
    sms["drain_src"] = jnp.where(start, pick.astype(jnp.int32),
                                 sms["drain_src"])
    sms["drain_left"] = jnp.where(
        start, batch_len[jnp.arange(C), pick], sms["drain_left"])
    sms["rr_ptr"] = jnp.where(start & ~use_sjf, (pick + 1) % S,
                              sms["rr_ptr"]).astype(jnp.int32)

    # --- drain one request per channel into the DCS ---
    draining = sms["drain_left"] > 0
    s = jnp.clip(sms["drain_src"], 0, S - 1)                # (C,)
    cidx = jnp.arange(C)
    head = sms["f_head"][cidx, s]
    row = sms["f_row"][cidx, s, head]
    bank = sms["f_bank"][cidx, s, head]
    birth = sms["f_birth"][cidx, s, head]
    has_req = sms["f_len"][cidx, s] > 0
    # safety: a desynced drain counter on an empty FIFO must not deadlock
    sms["drain_left"] = jnp.where(draining & ~has_req, 0, sms["drain_left"])
    dcs_room = sms["d_len"][cidx, bank] < D
    do = draining & has_req & dcs_room
    # pop stage-1
    sms["f_head"] = sms["f_head"].at[cidx, s].set(
        jnp.where(do, (head + 1) % F, head))
    sms["f_len"] = sms["f_len"].at[cidx, s].add(jnp.where(do, -1, 0))
    sms["drain_left"] = sms["drain_left"] - do.astype(jnp.int32)
    # push stage-3
    dslot = (sms["d_head"][cidx, bank] + sms["d_len"][cidx, bank]) % D
    bsafe = jnp.where(do, bank, 0)
    dsafe = jnp.where(do, dslot, 0)
    wr = lambda a, v: a.at[cidx, bsafe, dsafe].set(
        jnp.where(do, v, a[cidx, bsafe, dsafe]))
    sms["d_row"] = wr(sms["d_row"], row)
    sms["d_src"] = wr(sms["d_src"], s.astype(jnp.int32))
    sms["d_birth"] = wr(sms["d_birth"], birth)
    sms["d_len"] = sms["d_len"].at[cidx, bsafe].add(jnp.where(do, 1, 0))
    return st, sms


def stage3_issue(cfg: SimConfig, st, sms, dram, t):
    """DCS: issue from per-bank FIFO heads, RR across eligible banks.

    All channels resolve at once: per-channel picks are independent (each
    touches only its own DCS/DRAM rows) and issue side effects commute.
    """
    C, B, D = cfg.n_channels, cfg.n_banks, cfg.dcs_size
    sms = dict(sms)
    cidx = jnp.arange(C)
    head = sms["d_head"]                                    # (C,B)
    at_head = lambda a: jnp.take_along_axis(a, head[..., None], 2)[..., 0]
    row = at_head(sms["d_row"])                             # (C,B)
    src = at_head(sms["d_src"])
    birth = at_head(sms["d_birth"])
    valid = sms["d_len"] > 0
    elig, lat, is_hit = jax.vmap(
        lambda c, r, v: engine.eligibility(cfg, dram, c, jnp.arange(B), r,
                                           v, t))(cidx, row, valid)
    rr_key = jnp.where(elig, (jnp.arange(B)[None, :]
                              - sms["rr_bank"][:, None]) % B, 1 << 28)
    pick = jnp.argmin(rr_key, axis=1)                       # (C,)
    at_pick = lambda a: jnp.take_along_axis(a, pick[:, None], 1)[:, 0]
    do = at_pick(elig)
    dram, st = engine.issue_channels(
        cfg, dram, st, do, pick, at_pick(row), at_pick(src), at_pick(birth),
        at_pick(lat), at_pick(is_hit), t)
    psafe = jnp.where(do, pick, 0)
    head_p = head[cidx, psafe]
    sms["d_head"] = sms["d_head"].at[cidx, psafe].set(
        jnp.where(do, (head_p + 1) % D, head_p))
    sms["d_len"] = sms["d_len"].at[cidx, psafe].add(jnp.where(do, -1, 0))
    sms["rr_bank"] = jnp.where(do, (pick + 1) % B,
                               sms["rr_bank"]).astype(jnp.int32)
    return st, sms, dram
