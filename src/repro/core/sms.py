"""Staged Memory Scheduler — the paper's contribution (§2).

Three decoupled stages, all simple FIFOs:
  1. per-source batch formation FIFOs (C, S, F): consecutive same-(bank,row)
     requests form a batch; ready on row-change / age threshold / full FIFO;
  2. batch scheduler: picks a ready batch — SJF (fewest in-flight across all
     stages) with probability p, round-robin with 1-p — then drains it one
     request/cycle into stage 3;
  3. DRAM command scheduler (DCS): per-bank FIFOs (C, B, D); only FIFO heads
     issue; DRAM timing legality enforced; round-robin across banks.

Unlike the centralized schedulers there is no CAM scan: every structure is a
head/length circular FIFO — which is exactly the power/area claim §5.2
audits.

These stage functions are the implementation behind the registered "sms" /
"sms_dash" `MemoryPolicy` objects (see `repro.core.policies.sms`): stages 1+2
form the policy's `tick`, stage 3 its `select`. Every stage is a whole-array
op over all channels at once — no Python channel loop — so trace size and
compile time are independent of `n_channels`.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.params import CLS_HWA, SimConfig, static_bool


def sms_state(cfg: SimConfig) -> Dict[str, Any]:
    C, S, F = cfg.n_channels, cfg.n_src, cfg.fifo_size
    B, D = cfg.n_banks, cfg.dcs_size
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    return {
        # stage 1: per-source FIFOs
        "f_row": zi(C, S, F), "f_bank": zi(C, S, F), "f_birth": zi(C, S, F),
        "f_head": zi(C, S), "f_len": zi(C, S),
        # front_run: length of the front same-(bank,row) run of each FIFO
        # (the next batch), maintained incrementally at push/pop so stage 2
        # never re-gathers the full (C,S,F) FIFO view
        "front_run": zi(C, S),
        # stage 2: batch scheduler
        "drain_src": jnp.full((C,), -1, jnp.int32),
        "drain_left": zi(C),
        "rr_ptr": zi(C),
        "rng2": jnp.arange(1, C + 1, dtype=jnp.uint32) * jnp.uint32(40503),
        # stage 3: per-bank DCS FIFOs
        "d_row": zi(C, B, D), "d_src": zi(C, B, D), "d_birth": zi(C, B, D),
        "d_head": zi(C, B), "d_len": zi(C, B), "rr_bank": zi(C),
    }


def _run_from_head(rows, banks, head, length, F):
    """Front same-(bank,row) run length of one FIFO per channel.

    rows/banks: (C, F) slot arrays; head/length: (C,). Only used on the
    rare pop-exhausted-a-batch path, for the single drained source per
    channel — O(C·F), not O(C·S·F).
    """
    idx = (head[:, None] + jnp.arange(F)) % F
    rows_o = jnp.take_along_axis(rows, idx, axis=-1)
    banks_o = jnp.take_along_axis(banks, idx, axis=-1)
    in_r = jnp.arange(F) < length[:, None]
    eq = (rows_o == rows_o[:, :1]) & (banks_o == banks_o[:, :1]) & in_r
    return jnp.sum(jnp.cumprod(eq.astype(jnp.int32), axis=-1), axis=-1)


def batch_info(cfg: SimConfig, sms: Dict[str, Any], t):
    """(C,S) arrays: batch_len (front same-(bank,row) run) and readiness.

    batch_len is the incrementally-maintained `front_run` counter; only the
    head birth is gathered (O(C·S)), never the full FIFO contents.
    """
    batch_len = sms["front_run"]
    nonempty = sms["f_len"] > 0
    row_changed = batch_len < sms["f_len"]
    head_birth = jnp.take_along_axis(
        sms["f_birth"], sms["f_head"][..., None], axis=-1)[..., 0]  # (C,S)
    aged = nonempty & (t - head_birth >= cfg.batch_age_cap)
    full = sms["f_len"] >= cfg.fifo_size
    ready = nonempty & (row_changed | aged | full)
    return batch_len, ready


def stage1_admit(cfg: SimConfig, st, sms, t):
    """Decentralized admission: every source pushes into its own FIFO."""
    C, S, F = cfg.n_channels, cfg.n_src, cfg.fifo_size
    st = dict(st)
    sms = dict(sms)
    ch = engine.channel_of(cfg, st["pend_bank"])            # (S,)
    sidx = jnp.arange(S)
    flen = sms["f_len"][ch, sidx]
    room = flen < F
    do = st["pend_valid"] & room
    head = sms["f_head"][ch, sidx]
    slot = (head + flen) % F
    new_bank = engine.bank_in_channel(cfg, st["pend_bank"])
    # each source maps to exactly one channel this cycle: one-hot masked
    # writes (no scatters in the hot loop)
    mask_cs = (jnp.arange(C)[:, None] == ch[None, :]) & do[None, :]  # (C,S)
    mask_csf = mask_cs[:, :, None] & \
        (jnp.arange(F)[None, None, :] == slot[None, :, None])     # (C,S,F)
    # front_run: a push extends the front batch only when the whole FIFO is
    # that batch (front_run == f_len) and the new request matches its
    # (bank, row); a push into an empty FIFO starts a run of 1
    fr = sms["front_run"][ch, sidx]
    extend = (fr == flen) & \
        (st["pend_row"] == sms["f_row"][ch, sidx, head]) & \
        (new_bank == sms["f_bank"][ch, sidx, head])
    new_fr = jnp.where(flen == 0, 1, jnp.where(extend, fr + 1, fr))
    sms["front_run"] = jnp.where(mask_cs, new_fr[None, :],
                                 sms["front_run"])
    wr = lambda a, v: jnp.where(mask_csf, v[None, :, None], a)
    sms["f_row"] = wr(sms["f_row"], st["pend_row"])
    sms["f_bank"] = wr(sms["f_bank"], new_bank)
    sms["f_birth"] = wr(sms["f_birth"], st["pend_birth"])
    sms["f_len"] = sms["f_len"] + mask_cs.astype(jnp.int32)
    st["pend_valid"] = st["pend_valid"] & ~do
    return st, sms


def stage2_drain(cfg: SimConfig, pool, st, sms, t):
    """Pick ready batches (SJF w.p. p / RR w.p. 1-p) and drain 1 req/cycle."""
    C, S, F = cfg.n_channels, cfg.n_src, cfg.fifo_size
    B, D = cfg.n_banks, cfg.dcs_size
    sms = dict(sms)
    batch_len, ready = batch_info(cfg, sms, t)

    # --- pick a new batch on idle channels ---
    idle = sms["drain_left"] <= 0
    rng2, u = engine.lcg_step(sms["rng2"])
    sms["rng2"] = rng2
    use_sjf = u < cfg.sjf_prob                              # (C,)
    inflight = (st["emitted"] - st["completed"]).astype(jnp.int32)  # (S,)
    sjf_key = jnp.where(ready, inflight[None, :], 1 << 28)  # (C,S)
    sjf_pick = jnp.argmin(sjf_key, axis=-1)
    rr_off = (jnp.arange(S)[None, :] - sms["rr_ptr"][:, None]) % S
    rr_key = jnp.where(ready, rr_off, 1 << 28)
    rr_pick = jnp.argmin(rr_key, axis=-1)
    pick = jnp.where(use_sjf, sjf_pick, rr_pick)
    # `dash` is a value knob: statically False keeps the block out of the
    # trace entirely (the legacy SMS trace); statically True is the legacy
    # sms_dash trace; a traced/batched knob keeps the block and masks the
    # preemption with the knob itself.
    dash_on = static_bool(cfg.dash)
    if dash_on is not False:
        # SMS-DASH (paper §7 / Usui et al.): an HWA whose frame slack is
        # below its estimated remaining service time preempts the SJF/RR
        # choice; least-slack-first among urgent ready batches.
        has_dl = (pool["src_class"] == CLS_HWA) & (pool["dl_period"] > 0)
        remaining = jnp.maximum(pool["dl_reqs"] - st["period_done"], 0)
        time_left = pool["dl_period"] - jnp.mod(
            t, jnp.maximum(pool["dl_period"], 1))
        slack = time_left.astype(jnp.float32) - \
            remaining.astype(jnp.float32) * cfg.dash_svc_est
        urgent = has_dl & (slack < 0.0) & (remaining > 0)
        urgent_ready = ready & urgent[None, :]
        u_key = jnp.where(urgent_ready, slack[None, :], jnp.float32(1e30))
        u_pick = jnp.argmin(u_key, axis=-1)
        any_urgent = jnp.any(urgent_ready, axis=-1)
        if dash_on is None:
            any_urgent = any_urgent & cfg.dash
        pick = jnp.where(any_urgent, u_pick, pick)
        use_sjf = use_sjf | any_urgent          # don't advance rr on preempt
    any_ready = jnp.any(ready, axis=-1)
    start = idle & any_ready
    sms["drain_src"] = jnp.where(start, pick.astype(jnp.int32),
                                 sms["drain_src"])
    sms["drain_left"] = jnp.where(
        start, batch_len[jnp.arange(C), pick], sms["drain_left"])
    sms["rr_ptr"] = jnp.where(start & ~use_sjf, (pick + 1) % S,
                              sms["rr_ptr"]).astype(jnp.int32)

    # --- drain one request per channel into the DCS ---
    draining = sms["drain_left"] > 0
    s = jnp.clip(sms["drain_src"], 0, S - 1)                # (C,)
    cidx = jnp.arange(C)
    head = sms["f_head"][cidx, s]
    row = sms["f_row"][cidx, s, head]
    bank = sms["f_bank"][cidx, s, head]
    birth = sms["f_birth"][cidx, s, head]
    has_req = sms["f_len"][cidx, s] > 0
    # safety: a desynced drain counter on an empty FIFO must not deadlock
    sms["drain_left"] = jnp.where(draining & ~has_req, 0, sms["drain_left"])
    dcs_room = sms["d_len"][cidx, bank] < D
    do = draining & has_req & dcs_room
    # pop stage-1
    new_head = jnp.where(do, (head + 1) % F, head)
    new_len = sms["f_len"][cidx, s] - do.astype(jnp.int32)
    sms["f_head"] = engine.masked_set(sms["f_head"], s, new_head, do)
    sms["f_len"] = engine.masked_add(sms["f_len"], s, -1, do)
    sms["drain_left"] = sms["drain_left"] - do.astype(jnp.int32)
    # front_run: the pop shortens the front batch by one; when it exhausts
    # the batch with requests left, rescan just this source's FIFO (O(C·F))
    # for the next batch's run length
    fr = sms["front_run"][cidx, s] - do.astype(jnp.int32)
    rescan = do & (fr == 0) & (new_len > 0)
    fr = jnp.where(rescan,
                   _run_from_head(sms["f_row"][cidx, s],
                                  sms["f_bank"][cidx, s],
                                  new_head, new_len, F),
                   fr)
    sms["front_run"] = engine.masked_set(sms["front_run"], s, fr, do)
    # push stage-3
    dslot = (sms["d_head"][cidx, bank] + sms["d_len"][cidx, bank]) % D
    wr = lambda a, v: engine.masked_set2(a, bank, dslot, v, do)
    sms["d_row"] = wr(sms["d_row"], row)
    sms["d_src"] = wr(sms["d_src"], s.astype(jnp.int32))
    sms["d_birth"] = wr(sms["d_birth"], birth)
    sms["d_len"] = engine.masked_add(sms["d_len"], bank, 1, do)
    return st, sms


def stage3_issue(cfg: SimConfig, st, sms, dram, t):
    """DCS: issue from per-bank FIFO heads, RR across eligible banks.

    All channels resolve at once: per-channel picks are independent (each
    touches only its own DCS/DRAM rows) and issue side effects commute.
    """
    C, B, D = cfg.n_channels, cfg.n_banks, cfg.dcs_size
    sms = dict(sms)
    cidx = jnp.arange(C)
    head = sms["d_head"]                                    # (C,B)
    at_head = lambda a: jnp.take_along_axis(a, head[..., None], 2)[..., 0]
    row = at_head(sms["d_row"])                             # (C,B)
    src = at_head(sms["d_src"])
    birth = at_head(sms["d_birth"])
    valid = sms["d_len"] > 0
    elig, lat, is_hit = jax.vmap(
        lambda c, r, v: engine.eligibility(cfg, dram, c, jnp.arange(B), r,
                                           v, t))(cidx, row, valid)
    rr_key = jnp.where(elig, (jnp.arange(B)[None, :]
                              - sms["rr_bank"][:, None]) % B, 1 << 28)
    pick = jnp.argmin(rr_key, axis=1)                       # (C,)
    at_pick = lambda a: jnp.take_along_axis(a, pick[:, None], 1)[:, 0]
    do = at_pick(elig)
    dram, st = engine.issue_channels(
        cfg, dram, st, do, pick, at_pick(row), at_pick(src), at_pick(birth),
        at_pick(lat), at_pick(is_hit), t)
    head_p = head[cidx, jnp.where(do, pick, 0)]
    sms["d_head"] = engine.masked_set(sms["d_head"], pick, (head_p + 1) % D,
                                      do)
    sms["d_len"] = engine.masked_add(sms["d_len"], pick, -1, do)
    sms["rr_bank"] = jnp.where(do, (pick + 1) % B,
                               sms["rr_bank"]).astype(jnp.int32)
    return st, sms, dram


# ---------------------------------------------------------------------------
# variable-step driver witnesses (ROADMAP "Variable-step driver contract")
# ---------------------------------------------------------------------------

def next_stage_event(cfg: SimConfig, st, sms, dram, t):
    """Earliest cycle > t at which any of the three stages could act.

    Conservative-early like the centralized witnesses: stage 1 fires while
    any pending register has FIFO room; stage 2 fires while any channel is
    draining or could start a batch, plus the age-threshold time at which a
    quiet front batch becomes ready; stage 3 inverts the DRAM timing gates
    on the per-bank DCS heads. The dash urgency pick needs no witness of
    its own — it is recomputed from scratch on every processed cycle and
    only consulted when a drain starts, which is itself witnessed.
    """
    tm = cfg.timing
    INF = jnp.int32(engine.INF_T)
    t1 = t + 1
    # stage 1: a pending register with FIFO room pushes next cycle
    ch = engine.channel_of(cfg, st["pend_bank"])             # (S,)
    room = sms["f_len"][ch, jnp.arange(cfg.n_src)] < cfg.fifo_size
    w1 = jnp.where(jnp.any(st["pend_valid"] & room), t1, INF)
    # stage 2: an active drain moves (or settles) every cycle; an idle
    # channel starts as soon as any batch is ready
    _, ready = batch_info(cfg, sms, t)
    idle = sms["drain_left"] <= 0
    act = jnp.any(~idle) | jnp.any(idle & jnp.any(ready, axis=-1))
    w2 = jnp.where(act, t1, INF)
    # aging: a nonempty, not-yet-ready FIFO turns ready at head_birth + cap
    head_birth = jnp.take_along_axis(
        sms["f_birth"], sms["f_head"][..., None], axis=-1)[..., 0]  # (C,S)
    w_age = jnp.min(jnp.where(
        (sms["f_len"] > 0) & ~ready,
        jnp.maximum(head_birth + cfg.batch_age_cap, t1), INF))
    # stage 3: DCS head issue-eligibility times (inverts the three
    # `engine.eligibility` gates; their inputs are frozen while no issue
    # lands, which the witness itself guarantees for the span)
    at_head = lambda a: jnp.take_along_axis(a, sms["d_head"][..., None],
                                            2)[..., 0]        # (C,B)
    row = at_head(sms["d_row"])
    openv = dram["open_valid"]
    is_hit = openv & (dram["open_row"] == row)
    lat = jnp.where(is_hit, tm.lat_hit,
                    jnp.where(openv, tm.lat_conflict, tm.lat_closed)
                    ).astype(jnp.int32)
    faw_ready = jnp.min(dram["act_ring"], axis=1)[:, None] + tm.t_faw
    tau = jnp.maximum(dram["bank_free"],
                      jnp.where(is_hit, engine.NEG_T, faw_ready))
    tau = jnp.maximum(tau, dram["bus_free"][:, None] - lat)
    tau = jnp.maximum(tau, t1)
    w3 = jnp.min(jnp.where(sms["d_len"] > 0, tau, INF))
    return jnp.minimum(jnp.minimum(w1, w2), jnp.minimum(w_age, w3))


def skip_cycles(sms: Dict[str, Any], k) -> Dict[str, Any]:
    """Replay k skipped cycles of stage-2 state in closed form: the batch
    scheduler draws `rng2` once per cycle unconditionally."""
    sms = dict(sms)
    sms["rng2"] = engine.lcg_skip(sms["rng2"], k)
    return sms


# ---------------------------------------------------------------------------
# invariant-sanitizer hooks (repro.core.validate; traced only when
# cfg.validate_enabled — ROADMAP "Validation & fault-injection contract")
# ---------------------------------------------------------------------------

def check_invariants(cfg: SimConfig, sms: Dict[str, Any], t):
    """Count of violated staged-structure invariants: FIFO/DCS occupancy
    within declared bounds, heads in range, `front_run` matching a full
    recount, a non-negative drain counter, and the stage-2 rng stream at
    its closed-form position (one draw per cycle, ticked or skipped)."""
    C, F, D = cfg.n_channels, cfg.fifo_size, cfg.dcs_size
    n = lambda x: jnp.sum(jnp.asarray(x, jnp.int32))
    bad = n((sms["f_len"] < 0) | (sms["f_len"] > F))
    bad += n((sms["f_head"] < 0) | (sms["f_head"] >= F))
    bad += n((sms["d_len"] < 0) | (sms["d_len"] > D))
    bad += n((sms["d_head"] < 0) | (sms["d_head"] >= D))
    bad += n(sms["drain_left"] < 0)
    bad += n((sms["front_run"] < 0) | (sms["front_run"] > sms["f_len"]))
    bad += n((sms["f_len"] > 0) & (sms["front_run"] == 0))
    run = jax.vmap(lambda r, b, h, l: _run_from_head(r, b, h, l, F),
                   in_axes=(1, 1, 1, 1), out_axes=1)(
        sms["f_row"], sms["f_bank"], sms["f_head"], sms["f_len"])
    bad += n((sms["f_len"] > 0) & (run != sms["front_run"]))
    rng0 = jnp.arange(1, C + 1, dtype=jnp.uint32) * jnp.uint32(40503)
    bad += n(sms["rng2"] != engine.lcg_skip(rng0, t + 1))
    return bad


def audit_skip(cfg: SimConfig, st, sms: Dict[str, Any], dram, t, t_new):
    """Would-fire lateness predicates for a jumped span, re-derived from the
    stage conditions at the last skipped cycle u (stage state is frozen over
    a span; only the age predicate is t-dependent, and it is monotone).
    Stage-1 pushes report as late_admission, stage-2 batch events as
    late_boundary, stage-3 DCS-head eligibility as late_issue."""
    u = t_new - 1
    skipped = t_new - t > 1
    ch = engine.channel_of(cfg, st["pend_bank"])
    room = sms["f_len"][ch, jnp.arange(cfg.n_src)] < cfg.fifo_size
    s1 = jnp.any(st["pend_valid"] & room)
    _, ready = batch_info(cfg, sms, u)
    idle = sms["drain_left"] <= 0
    s2 = jnp.any(~idle) | jnp.any(idle & jnp.any(ready, axis=-1))
    at_head = lambda a: jnp.take_along_axis(a, sms["d_head"][..., None],
                                            2)[..., 0]
    row = at_head(sms["d_row"])
    valid = sms["d_len"] > 0
    elig, _, _ = jax.vmap(
        lambda c, r, v: engine.eligibility(
            cfg, dram, c, jnp.arange(cfg.n_banks), r, v, u)
    )(jnp.arange(cfg.n_channels), row, valid)
    b = lambda x: (skipped & x).astype(jnp.int32)
    return {"late_admission": b(s1), "late_boundary": b(s2),
            "late_issue": b(jnp.any(elig))}
