"""Structure-count power/area proxy model (§5.2 reproduction).

The centralized schedulers need a CAM over the whole request buffer (row
match for FR-FCFS hit detection + global age/priority search each cycle) and
per-entry ranking logic. SMS needs only SRAM FIFOs with head/tail pointers
and a handful of small comparators.

Per-bit constants (relative units; CAM ~9–10T vs 6T SRAM, match-line
leakage; ranking comparators dominated by per-entry priority encode):
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.params import SimConfig

# relative cost per bit (CAM cell 9-10T vs 6T SRAM; match-line leakage)
SRAM_AREA = 1.0
CAM_AREA = 1.7
SRAM_LEAK = 1.0
CAM_LEAK = 3.0

ENTRY_BITS_PAYLOAD = 48      # src, birth, metadata (SRAM in both designs)
ENTRY_BITS_MATCH = 24        # row+bank tag (CAM in centralized, SRAM in SMS)
CMP_AREA_PER_ENTRY = 20.0    # age/priority comparator tree, per entry
CMP_LEAK_PER_ENTRY = 20.0
FIFO_CTRL_AREA = 24.0        # head/tail pointers + full/empty per FIFO
FIFO_CTRL_LEAK = 10.0
RANK_LOGIC_AREA_PER_SRC = 60.0   # ATLAS/TCM/PAR-BS ranking (per source)
RANK_LOGIC_LEAK_PER_SRC = 40.0


def centralized_cost(cfg: SimConfig, policy: str = "frfcfs") -> Dict[str, float]:
    entries = cfg.n_channels * cfg.buf_entries
    area = entries * (ENTRY_BITS_MATCH * CAM_AREA +
                      ENTRY_BITS_PAYLOAD * SRAM_AREA + CMP_AREA_PER_ENTRY)
    leak = entries * (ENTRY_BITS_MATCH * CAM_LEAK +
                      ENTRY_BITS_PAYLOAD * SRAM_LEAK + CMP_LEAK_PER_ENTRY)
    if policy != "frfcfs":
        area += cfg.n_src * RANK_LOGIC_AREA_PER_SRC
        leak += cfg.n_src * RANK_LOGIC_LEAK_PER_SRC
    return {"area": area, "leakage": leak, "entries": entries}


def sms_cost(cfg: SimConfig) -> Dict[str, float]:
    s1_entries = cfg.n_channels * cfg.n_src * cfg.fifo_size
    s3_entries = cfg.n_channels * cfg.n_banks * cfg.dcs_size
    entries = s1_entries + s3_entries
    n_fifos = cfg.n_channels * (cfg.n_src + cfg.n_banks)
    bits = ENTRY_BITS_MATCH + ENTRY_BITS_PAYLOAD
    area = entries * bits * SRAM_AREA + n_fifos * FIFO_CTRL_AREA \
        + cfg.n_channels * (cfg.n_src * 8.0)   # batch scheduler compare
    leak = entries * bits * SRAM_LEAK + n_fifos * FIFO_CTRL_LEAK \
        + cfg.n_channels * (cfg.n_src * 5.0)
    return {"area": area, "leakage": leak, "entries": entries}


def compare(cfg: SimConfig) -> Dict[str, float]:
    fr = centralized_cost(cfg, "frfcfs")
    sm = sms_cost(cfg)
    return {
        "frfcfs_area": fr["area"], "sms_area": sm["area"],
        "frfcfs_leakage": fr["leakage"], "sms_leakage": sm["leakage"],
        "area_reduction_pct": 100.0 * (1 - sm["area"] / fr["area"]),
        "leakage_reduction_pct": 100.0 * (1 - sm["leakage"] / fr["leakage"]),
        "frfcfs_entries": fr["entries"], "sms_entries": sm["entries"],
    }
