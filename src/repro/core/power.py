"""Structure-count power/area proxy model (§5.2 reproduction) + the
full-MC energy combine.

The centralized schedulers need a CAM over the whole request buffer (row
match for FR-FCFS hit detection + global age/priority search each cycle) and
per-entry ranking logic. SMS needs only SRAM FIFOs with head/tail pointers
and a handful of small comparators.

Per-bit constants (relative units; CAM ~9–10T vs 6T SRAM, match-line
leakage; ranking comparators dominated by per-entry priority encode):

`full_mc_energy` closes the loop with `repro.core.energy`: the static
scheduler-structure leakage (these relative units, scaled to nJ/cycle by
`LEAK_NJ_PER_UNIT_CYCLE`) plus the measured dynamic DRAM totals give the
whole-memory-controller energy picture the paper's "energy-efficient"
claim is about.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.params import SimConfig

# relative cost per bit (CAM cell 9-10T vs 6T SRAM; match-line leakage)
SRAM_AREA = 1.0
CAM_AREA = 1.7
SRAM_LEAK = 1.0
CAM_LEAK = 3.0

ENTRY_BITS_PAYLOAD = 48      # src, birth, metadata (SRAM in both designs)
ENTRY_BITS_MATCH = 24        # row+bank tag (CAM in centralized, SRAM in SMS)
CMP_AREA_PER_ENTRY = 20.0    # age/priority comparator tree, per entry
CMP_LEAK_PER_ENTRY = 20.0
FIFO_CTRL_AREA = 24.0        # head/tail pointers + full/empty per FIFO
FIFO_CTRL_LEAK = 10.0
RANK_LOGIC_AREA_PER_SRC = 60.0   # ATLAS/TCM/PAR-BS ranking (per source)
RANK_LOGIC_LEAK_PER_SRC = 40.0


def centralized_cost(cfg: SimConfig, policy: str = "frfcfs") -> Dict[str, float]:
    entries = cfg.n_channels * cfg.buf_entries
    area = entries * (ENTRY_BITS_MATCH * CAM_AREA +
                      ENTRY_BITS_PAYLOAD * SRAM_AREA + CMP_AREA_PER_ENTRY)
    leak = entries * (ENTRY_BITS_MATCH * CAM_LEAK +
                      ENTRY_BITS_PAYLOAD * SRAM_LEAK + CMP_LEAK_PER_ENTRY)
    if policy != "frfcfs":
        area += cfg.n_src * RANK_LOGIC_AREA_PER_SRC
        leak += cfg.n_src * RANK_LOGIC_LEAK_PER_SRC
    return {"area": area, "leakage": leak, "entries": entries}


def sms_cost(cfg: SimConfig) -> Dict[str, float]:
    s1_entries = cfg.n_channels * cfg.n_src * cfg.fifo_size
    s3_entries = cfg.n_channels * cfg.n_banks * cfg.dcs_size
    entries = s1_entries + s3_entries
    n_fifos = cfg.n_channels * (cfg.n_src + cfg.n_banks)
    bits = ENTRY_BITS_MATCH + ENTRY_BITS_PAYLOAD
    area = entries * bits * SRAM_AREA + n_fifos * FIFO_CTRL_AREA \
        + cfg.n_channels * (cfg.n_src * 8.0)   # batch scheduler compare
    leak = entries * bits * SRAM_LEAK + n_fifos * FIFO_CTRL_LEAK \
        + cfg.n_channels * (cfg.n_src * 5.0)
    return {"area": area, "leakage": leak, "entries": entries}


# leakage-unit -> nJ/cycle conversion for the full-MC energy combine. At
# the §5.2 configuration this puts the centralized CAM scheduler's static
# power at a few nJ/cycle — same order as the DRAM dynamic power it
# schedules, which is the regime where the paper's structure-simplification
# argument bites.
LEAK_NJ_PER_UNIT_CYCLE = 2e-5


def structure_cost(cfg: SimConfig, policy: str) -> Dict[str, float]:
    """Area/leakage of the scheduler structures `policy` needs."""
    if policy.startswith("sms"):
        return sms_cost(cfg)
    return centralized_cost(cfg, policy)


def scheduler_static_power(cfg: SimConfig, policy: str) -> float:
    """Scheduler-structure leakage power in nJ/cycle (for energy_breakdown)."""
    return structure_cost(cfg, policy)["leakage"] * LEAK_NJ_PER_UNIT_CYCLE


def full_mc_energy(cfg: SimConfig, policy: str, dram_dynamic_nj: float,
                   dram_background_nj: float, n_cycles: int,
                   requests: float) -> Dict[str, float]:
    """Static scheduler leakage + measured dynamic DRAM totals, per request.

    dram_dynamic_nj / dram_background_nj come from the `energy_*` counters
    (`metrics.energy_breakdown` or raw `simulate` outputs) over `n_cycles`
    measured cycles in which `requests` requests completed.
    """
    static = scheduler_static_power(cfg, policy) * n_cycles
    total = static + dram_dynamic_nj + dram_background_nj
    reqs = max(requests, 1.0)
    return {
        "scheduler_static_nj": static,
        "dram_dynamic_nj": dram_dynamic_nj,
        "dram_background_nj": dram_background_nj,
        "total_nj": total,
        "energy_per_request_nj": total / reqs,
        "static_frac": static / max(total, 1e-9),
    }


def compare(cfg: SimConfig) -> Dict[str, float]:
    fr = centralized_cost(cfg, "frfcfs")
    sm = sms_cost(cfg)
    return {
        "frfcfs_area": fr["area"], "sms_area": sm["area"],
        "frfcfs_leakage": fr["leakage"], "sms_leakage": sm["leakage"],
        "area_reduction_pct": 100.0 * (1 - sm["area"] / fr["area"]),
        "leakage_reduction_pct": 100.0 * (1 - sm["leakage"] / fr["leakage"]),
        "frfcfs_entries": fr["entries"], "sms_entries": sm["entries"],
    }
