"""Deterministic fault injection for the invariant sanitizer.

Each injector perturbs exactly one contract the sanitizer
(`repro.core.validate`) guards, by temporarily monkeypatching the module
attribute the hot loop resolves at trace time. The point is falsifiable
self-checking: a sanitizer that never fires on a healthy run proves
nothing unless each violation class is ALSO shown to fire under a fault
engineered to break it (tests/test_validate.py drives every registered
fault through this harness and asserts its targeted counters flip).

Injection contract:

  * `inject(name)` is a context manager — patch on entry, restore on exit,
    exception-safe. Faults are pure attribute swaps; no global state
    outside the `with` block.
  * Patched callables are resolved at TRACE time, so injected runs must
    build fresh programs: use `simulator.simulate_debug` /
    `simulate_debug_stacked` (fresh `jax.jit` per call), never the cached
    `_sim_batch` dispatchers — a cached healthy trace would silently
    bypass the fault.
  * Injectors never touch `engine.lcg_skip` or other primitives the
    sanitizer itself calls: the checker must keep an independent view of
    ground truth, or the fault would cancel out of the comparison.

Registered faults (TARGETS maps each to the violation counters it must
trip; `skip_only` faults corrupt span machinery and need a variable-step
run to manifest):

  late_witness        source-event witness returns 16 cycles late, so the
                      driver jumps past wake-ups     -> late_source/...
  dropped_completion  completion ring slot zeroed before return-to-source,
                      requests vanish in flight      -> flow_conserve
  double_issue        issue mask forced on regardless of eligibility,
                      commands land on busy banks    -> busy_bank/...
  rng_skew            closed-form rng fast-forward off by one step per
                      span (the classic skip bug)    -> rng_stream
  stacked_writeset    "msub" dropped from PAR-BS's declared stacked
                      write-set, counter silently desyncs -> occupancy
"""
from __future__ import annotations

import contextlib
from typing import Dict, Tuple

import jax.numpy as jnp

from repro.core import engine, policy as policy_api, schedulers

# violation counters each fault must flip (asserted by tests; a fault may
# also trip collateral counters — e.g. forced issues corrupt conservation
# too — but at least one target must fire)
TARGETS: Dict[str, Tuple[str, ...]] = {
    "late_witness": ("late_source", "late_boundary", "late_admission",
                     "late_issue"),
    "dropped_completion": ("flow_conserve",),
    "double_issue": ("busy_bank", "bus_conflict", "tfaw"),
    "rng_skew": ("rng_stream",),
    "stacked_writeset": ("occupancy",),
}

# faults that corrupt the variable-step span machinery: a ticked run never
# exercises the broken path, so drivers must run with skip=True
SKIP_ONLY = ("late_witness", "rng_skew")

# faults that live on the stacked multi-policy path only
STACKED_ONLY = ("stacked_writeset",)


@contextlib.contextmanager
def _patched(obj, attr, value):
    orig = getattr(obj, attr)
    setattr(obj, attr, value)
    try:
        yield
    finally:
        setattr(obj, attr, orig)


# ---------------------------------------------------------------------------
# injectors — each returns a context manager
# ---------------------------------------------------------------------------

def _late_witness():
    """Source-event witness reports 16 cycles later than truth, so the
    skip driver trusts a span that contains real wake-ups. The span
    auditor's would-fire probes at u = t_new - 1 must catch it."""
    orig = engine.next_source_event

    def skewed(cfg, pool, st, active, t):
        return orig(cfg, pool, st, active, t) + jnp.int32(16)

    return _patched(engine, "next_source_event", skewed)


def _dropped_completion():
    """Zero the completion ring slot before it returns to its source:
    the request was emitted and issued but never completes, so in-flight
    flow conservation (outstanding vs pend+queued+ring) breaks."""
    orig = engine.completions_tick

    def dropping(st, dram, t):
        dram = dict(dram)
        dram["ring"] = dram["ring"].at[jnp.mod(t, engine.RING)].set(0)
        return orig(st, dram, t)

    return _patched(engine, "completions_tick", dropping)


def _double_issue():
    """Force the per-channel issue mask on by handing `issue_picked` the
    absolute score: ineligible picks (score < 0 encodes 'no legal
    candidate') get committed anyway, landing commands on busy banks,
    conflicting bus slots, and past the tFAW activate budget."""
    orig = schedulers.issue_picked

    def forced(cfg, st, buf, dram, score, lat, is_hit, t):
        return orig(cfg, st, buf, dram, jnp.abs(score), lat, is_hit, t)

    return _patched(schedulers, "issue_picked", forced)


def _rng_skew():
    """Advance the source rng by one extra step per skipped span — the
    canonical closed-form fast-forward off-by-one. The stream checker
    (rng must equal lcg_skip(rng0, 2(t+1)) at every real cycle) fires at
    the first post-span tick."""
    orig = engine.skip_sources

    def skewed(cfg, pool, st, active, k):
        st = orig(cfg, pool, st, active, k)
        st = dict(st)
        extra, _ = engine.lcg_step(st["rng"])
        st["rng"] = jnp.where(k > 0, extra, st["rng"])
        return st

    return _patched(engine, "skip_sources", skewed)


def _stacked_writeset():
    """Drop "msub" from PAR-BS's declared stacked write-sets. The stacked
    step only re-stacks declared keys, so the hook's updates to the
    would-be-marked counter are silently discarded and the mirror-counter
    recount in `check_invariants` desyncs (occupancy class)."""
    pol = policy_api.POLICY_REGISTRY.get("parbs")
    tick = tuple(k for k in pol.stacked_tick_keys if k != "msub")
    issue = tuple(k for k in pol.stacked_issue_keys if k != "msub")

    @contextlib.contextmanager
    def ctx():
        # instance attributes shadow the class declaration; delete to restore
        pol.stacked_tick_keys = tick
        pol.stacked_issue_keys = issue
        try:
            yield
        finally:
            del pol.stacked_tick_keys
            del pol.stacked_issue_keys

    return ctx()


FAULTS = {
    "late_witness": _late_witness,
    "dropped_completion": _dropped_completion,
    "double_issue": _double_issue,
    "rng_skew": _rng_skew,
    "stacked_writeset": _stacked_writeset,
}

assert set(FAULTS) == set(TARGETS)


def inject(name: str):
    """Context manager arming fault `name` (see FAULTS). Deterministic:
    same fault + same run -> same violation counters."""
    if name not in FAULTS:
        raise KeyError(f"unknown fault {name!r}; known: {sorted(FAULTS)}")
    return FAULTS[name]()
