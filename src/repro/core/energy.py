"""Command-level DRAM energy accounting (the paper's *energy-efficient* half).

`power.py` reproduces the §5.2 STATIC structure-count proxy (CAM vs FIFO
area/leakage). This module models the DYNAMIC energy that scheduling
decisions actually move, DRAMPower/Micron-power-calc style, as incrementally
maintained counters inside the per-cycle step:

  * ACT/PRE pair energy charged to the issuing source on every row miss
    (a hit re-uses the open row and pays no activate);
  * RD/WR burst energy charged to the issuing source on every issue;
  * background energy per channel-cycle — active-standby while any bank is
    busy or recently touched, power-down once a channel's banks have all
    been idle for >= `energy_pd_idle` cycles;
  * a wake-up penalty charged when a powered-down channel next admits a
    DRAM command (its first issue after the idle stretch).

The model is ENERGY-ONLY by contract: no counter ever feeds back into
eligibility, scoring, or timing (power-down exit latency is deliberately
not modeled), so enabling it leaves every scheduling decision bit-identical
— the golden-digest tests pin exactly that. Zero is a safe initial/padding
value for every counter, and all state is (S,)- or (C,)-shaped so it rides
the stacked cross-policy carry unchanged.

Hot-loop rules compliance: all updates are whole-(C,)/(S,) elementwise ops
or one-hot masked accumulations (rule 3 — no scatters); the power-down
state machine is maintained from the incremental `busy_until` watermark
(rule 2 — no per-cycle reduction over banks); nothing sorts (rule 1).

Background energy is held as integer CYCLE COUNTERS (`sb_cycles` standby,
`pd_cycles` power-down) rather than a float accumulator: the variable-step
driver charges a whole skipped span in one add, and only integer counters
make that bit-identical to per-cycle accrual (k repeated f32 adds of 0.10
!= one add of k*0.10). The nJ value is derived at metric time:

    energy_bg == energy_standby * sb_cycles + energy_pd * pd_cycles

Accounting identities (pinned by tests/test_energy.py):

    e_rw[s]  == energy_rw  * issued[s]
    e_act[s] == energy_act * (issued[s] - hits[s])
    sum(sb_cycles) + sum(pd_cycles) == C * cycles
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.params import SimConfig

# dram_state keys owned by this module (per-policy goldens exclude them;
# tests assert their presence so the additivity check is never vacuous)
STATE_KEYS = ("e_act", "e_rw", "sb_cycles", "e_wake", "pd_down", "pd_cycles",
              "busy_until")


def energy_state(cfg: SimConfig) -> Dict[str, Any]:
    """Energy counters merged into `engine.dram_state` when enabled.

    e_act/e_rw: per-source dynamic energy (nJ); sb_cycles/e_wake:
    per-channel standby-cycle counter + wake-up energy; pd_down/pd_cycles/
    busy_until: the power-down state machine (busy_until is the running max
    of bank busy horizons, maintained at issue — never recomputed from
    `bank_free`).
    """
    if not cfg.energy_enabled:
        return {}
    C, S = cfg.n_channels, cfg.n_src
    return {
        "e_act": jnp.zeros((S,), jnp.float32),
        "e_rw": jnp.zeros((S,), jnp.float32),
        "sb_cycles": jnp.zeros((C,), jnp.int32),
        "e_wake": jnp.zeros((C,), jnp.float32),
        "pd_down": jnp.zeros((C,), bool),
        "pd_cycles": jnp.zeros((C,), jnp.int32),
        "busy_until": jnp.zeros((C,), jnp.int32),
    }


def background_tick(cfg: SimConfig, dram: Dict[str, Any], t: jax.Array
                    ) -> Dict[str, Any]:
    """Per-cycle background accrual + power-down entry (all (C,) ops).

    A channel whose banks have all been idle for >= `energy_pd_idle`
    cycles (watermark `busy_until` is that far in the past) drops to
    power-down power; otherwise it pays active-standby power.
    """
    if not cfg.energy_enabled:
        return dram
    dram = dict(dram)
    idle_long = t - dram["busy_until"] >= cfg.energy_pd_idle
    pd = dram["pd_down"] | idle_long
    dram["pd_down"] = pd
    dram["sb_cycles"] = dram["sb_cycles"] + (~pd).astype(jnp.int32)
    dram["pd_cycles"] = dram["pd_cycles"] + pd.astype(jnp.int32)
    return dram


def skip_accrue(cfg: SimConfig, dram: Dict[str, Any], t: jax.Array,
                t_new: jax.Array) -> Dict[str, Any]:
    """Charge background cycles for the skipped span t+1 .. t_new-1 in one
    add — exactly what k = t_new-1-t calls of `background_tick` would do.

    Valid under the witness contract: no issue lands inside the span, so
    `busy_until` is frozen and the only transition is standby -> power-down
    at `enter = busy_until + energy_pd_idle`. The closed form splits the
    span at that entry cycle; the final `pd_down` OR is a no-op when k == 0
    (cycle t's own `background_tick` already applied the same predicate).
    """
    if not cfg.energy_enabled:
        return dram
    dram = dict(dram)
    k = t_new - 1 - t
    enter = dram["busy_until"] + cfg.energy_pd_idle
    n_pd = jnp.where(
        dram["pd_down"], k,
        jnp.clip(t_new - jnp.maximum(enter, t + 1), 0, k))
    dram["pd_cycles"] = dram["pd_cycles"] + n_pd
    dram["sb_cycles"] = dram["sb_cycles"] + (k - n_pd)
    dram["pd_down"] = dram["pd_down"] | (t_new - 1 >= enter)
    return dram


def on_issue(cfg: SimConfig, dram: Dict[str, Any], do_issue: jax.Array,
             src: jax.Array, is_hit: jax.Array, done: jax.Array
             ) -> Dict[str, Any]:
    """Charge command energy for this cycle's issues ((C,) vectors).

    Row misses pay an ACT/PRE pair on top of the burst; a powered-down
    channel admitting its first command wakes (energy penalty only — the
    scheduling timeline is untouched, keeping the accounting additive).
    """
    if not cfg.energy_enabled:
        return dram
    # deferred import: engine pulls in energy at module load (dram_state /
    # issue_channels), so the reverse edge must bind at trace time instead
    from repro.core import engine
    dram = dict(dram)
    dram["e_rw"] = engine.accum_by_index(
        dram["e_rw"], src, jnp.float32(cfg.energy_rw), do_issue)
    dram["e_act"] = engine.accum_by_index(
        dram["e_act"], src, jnp.float32(cfg.energy_act), do_issue & ~is_hit)
    wake = do_issue & dram["pd_down"]
    dram["e_wake"] = dram["e_wake"] + \
        wake.astype(jnp.float32) * jnp.float32(cfg.energy_wake)
    dram["pd_down"] = dram["pd_down"] & ~do_issue
    dram["busy_until"] = jnp.where(
        do_issue, jnp.maximum(dram["busy_until"], done), dram["busy_until"])
    return dram
