"""`MemoryPolicy` protocol + registry: one scheduler API for the whole repo.

The paper's thesis is that a memory controller is three decoupled tasks
behind a common interface. This module is that interface. A policy is an
object with

    name         registry key ("frfcfs", "sms", "bliss", ...)
    variant_of   None, or the name of the policy this one is a configured
                 variant of (variants are excluded from the baseline sweep)
    configure(cfg)                    -> cfg     (static/shape adjustments
                                         only; value knobs go through
                                         configure_knobs — see below)
    configure_knobs(knobs)            -> knobs   (optional: pin value-like
                                         knobs, e.g. sms_dash sets dash=True;
                                         the default is identity)
    init_state(cfg)                   -> sched   (pytree of jax arrays)
    tick(cfg, pool, st, sched, t)     -> (st, sched)        admission +
                                         periodic policy maintenance
    select(cfg, pool, st, sched, dram, t) -> (st, sched, dram)  pick + issue

and the simulator is one generic `lax.scan` body (`make_step`) over whatever
policy object the registry hands back — no string dispatch anywhere.

Registering a policy:

    from repro.core import policy
    from repro.core.schedulers import CentralizedPolicy

    @policy.register
    class Oldest(CentralizedPolicy):
        name = "oldest"
        def score(self, cfg, pool, buf, is_hit, t):
            ...

`Registry` itself is domain-agnostic; `repro.serving.scheduler` uses a
second instance so the serving engine and the cycle sim enumerate policies
the same way.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional, Protocol, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import energy, engine, params, telemetry, validate
from repro.core.params import Knobs, SimConfig


class MemoryPolicy(Protocol):
    """Structural type for cycle-sim scheduling policies."""

    name: str
    variant_of: Optional[str]

    def configure(self, cfg: SimConfig) -> SimConfig: ...

    def init_state(self, cfg: SimConfig) -> Dict[str, Any]: ...

    def tick(self, cfg: SimConfig, pool, st, sched, t): ...

    def select(self, cfg: SimConfig, pool, st, sched, dram, t): ...


class Registry:
    """Ordered name -> object registry with a decorator interface.

    Mapping-style access (`reg["sms"]`, `reg["sms"] = obj`, `"sms" in reg`,
    `reg.keys()`) is supported so call sites and tests can treat a registry
    like the plain dicts it replaces.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Any] = {}

    def register(self, name: Optional[str] = None) -> Callable:
        """Use as ``@reg.register("name")`` or ``@reg.register`` (reads
        the object's ``name`` attribute)."""
        def deco(obj, _name=name if isinstance(name, str) else None):
            key = _name or getattr(obj, "name", None)
            if not key:
                raise ValueError(f"{self.kind} needs a `name` to register")
            if key in self._entries:
                raise ValueError(f"duplicate {self.kind} {key!r}")
            self._entries[key] = obj
            return obj

        if name is None or isinstance(name, str):
            return deco
        return deco(name)                       # bare @reg.register on a class

    def get(self, name: str) -> Any:
        try:
            return self._entries[name]
        except KeyError:
            raise KeyError(f"unknown {self.kind} {name!r}; "
                           f"registered: {', '.join(self._entries)}") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._entries)

    def keys(self):
        return self._entries.keys()

    def items(self):
        return self._entries.items()

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __getitem__(self, name: str) -> Any:
        return self.get(name)

    def __setitem__(self, name: str, obj: Any) -> None:
        self._entries[name] = obj               # tests swap entries in-place


POLICY_REGISTRY = Registry("memory policy")


def register(cls):
    """Class decorator: instantiate and register a `MemoryPolicy`."""
    POLICY_REGISTRY.register(cls.name)(cls())
    return cls


def _ensure_builtin() -> None:
    # Lazy so `policy` stays import-cycle-free (policies import schedulers,
    # which imports engine); the built-ins self-register on first lookup.
    from repro.core import policies  # noqa: F401


def get(name: str) -> MemoryPolicy:
    _ensure_builtin()
    return POLICY_REGISTRY.get(name)


def names() -> Tuple[str, ...]:
    """All registered policies, in registration order."""
    _ensure_builtin()
    return POLICY_REGISTRY.names()


def baseline_names() -> Tuple[str, ...]:
    """Policies that are not configured variants of another policy."""
    _ensure_builtin()
    return tuple(n for n, p in POLICY_REGISTRY.items()
                 if getattr(p, "variant_of", None) is None)


def resolve_knobs(cfg: SimConfig, pol, knobs: Optional[Knobs] = None
                  ) -> Knobs:
    """The knob point a policy actually runs at: caller-supplied (or cfg
    defaults) filtered through the policy's optional `configure_knobs`."""
    kn = Knobs.from_cfg(cfg) if knobs is None else knobs
    ck = getattr(pol, "configure_knobs", None)
    return ck(kn) if ck is not None else kn


def is_stackable(name: str, cfg: SimConfig) -> bool:
    """True if `name` opts into the stacked cross-policy execution path.

    Stackability is declared by the policy (`stackable = True`, see
    `CentralizedPolicy`) AND requires `configure` to leave cfg untouched
    AND `configure_knobs` to be the identity at this config — stacked
    slices share one static config and, by default, cfg's knob point, so a
    policy that pins either (e.g. sms_dash's dash=True) must run the
    per-policy path.
    """
    pol = get(name)
    if not getattr(pol, "stackable", False) or pol.configure(cfg) != cfg:
        return False
    ck = getattr(pol, "configure_knobs", None)
    if ck is None:
        return True
    base = Knobs.from_cfg(cfg)
    resolved = ck(base)
    return all(np.asarray(getattr(resolved, f)) == np.asarray(getattr(base, f))
               for f in params.KNOB_FIELDS)


def make_step(cfg: SimConfig, pol: MemoryPolicy, pool, active):
    """One simulator cycle, generic over the policy object.

    `pool`/`active` are read-only per-workload parameters: they are closed
    over here (broadcast into the trace) rather than threaded through the
    scan carry, which keeps the carry pytree to genuinely cycle-varying
    state only.
    """

    def step(carry, t):
        st, sched, dram = carry
        if cfg.telemetry_enabled:
            snap = telemetry.snapshot(st, sched, dram)
        st, dram = engine.completions_tick(st, dram, t)
        dram = energy.background_tick(cfg, dram, t)
        st = engine.deadline_tick(cfg, pool, st, t)
        st = engine.source_tick(cfg, pool, st, active, t)
        st, sched = pol.tick(cfg, pool, st, sched, t)
        st, sched, dram = pol.select(cfg, pool, st, sched, dram, t)
        if cfg.telemetry_enabled:
            dram = telemetry.tick_accrue(cfg, pool, snap, st, sched, dram, t)
        if cfg.validate_enabled:
            # conservation laws hold as end-of-cycle identities
            dram = dict(dram)
            dram["viol"] = dram["viol"] + validate.tick_counts(
                cfg, pool, pol, st, sched, dram, t)
        return (st, sched, dram), None

    return step


def make_skip_step(cfg: SimConfig, pol: MemoryPolicy, pool, active):
    """Variable-step body: process cycle t fully, then jump to the next
    event (ROADMAP "Variable-step driver contract").

    Returns None when `pol` exposes no `next_event` witness — the driver
    then falls back to the ticked scan. The body runs the ordinary ticked
    `make_step` for cycle t, asks the engine + policy witnesses for the
    earliest cycle > t at which anything could happen, and replays the
    skipped span's closed-form accruals (source rng/instruction progress,
    background energy) in O(1). Hooks never observe the step size: they
    still see every processed cycle exactly as the ticked driver would.
    """
    if not hasattr(pol, "next_event"):
        return None
    step = make_step(cfg, pol, pool, active)
    on_skip = getattr(pol, "on_skip", None)

    def skip_body(carry, t, t_end):
        carry, _ = step(carry, t)
        st, sched, dram = carry
        te = engine.next_source_event(cfg, pool, st, active, t)
        te = jnp.minimum(te, engine.next_completion(dram, t))
        te = jnp.minimum(te, pol.next_event(cfg, pool, st, sched, dram, t))
        t_new = jnp.minimum(te, t_end)
        k = t_new - t - 1                       # skipped cycles, >= 0
        st = engine.skip_sources(cfg, pool, st, active, k)
        if cfg.telemetry_enabled:
            # before energy.skip_accrue: reads the pre-span pd_down
            dram = telemetry.skip_accrue(cfg, pool, st, dram, t, t_new)
        dram = energy.skip_accrue(cfg, dram, t, t_new)
        if on_skip is not None:
            sched = on_skip(cfg, sched, k)
        if cfg.validate_enabled:
            # lateness audit of the jumped span, on post-accrual state
            dram = dict(dram)
            dram["viol"] = dram["viol"] + validate.span_counts(
                cfg, pool, pol, st, sched, dram, active, t, t_new)
        return (st, sched, dram), t_new

    return skip_body
