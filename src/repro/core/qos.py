"""Per-class QoS accounting: request-latency histograms in the hot loop.

The N-class requester model (CPU+GPU+HWA) needs tail latency per class, but
quantiles cannot be maintained incrementally from `sum_lat` alone. This
module keeps a per-source latency histogram, accumulated at issue commit
(when a request's completion time is known), from which per-class p95/p99
are reduced host-side (`metrics.qos_breakdown`) — sources roll up to
classes by masking rows with `pool["src_class"]`.

Same contract as `repro.core.energy`: MEASUREMENT-ONLY. No histogram value
ever feeds back into eligibility, scoring, or timing, so flipping
`qos_enabled` leaves every scheduling decision bit-identical. Zero is a
safe initial/padding value, and the single (S, BINS) counter rides the
stacked cross-policy carry unchanged.

Hot-loop rules compliance: the accumulation is one (C, S, BINS) one-hot
mask summed over channels (rule 3 — no scatters), nothing sorts (rule 1),
nothing rescans (rule 2).

Accounting identity (pinned by tests/test_nclass.py):

    lat_hist[s].sum() == issued[s]
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import SimConfig

# dram_state keys owned by this module (golden digests exclude them; the
# digest tests whitelist exactly this tuple)
STATE_KEYS = ("lat_hist",)


def qos_state(cfg: SimConfig) -> Dict[str, Any]:
    """QoS counters merged into `engine.dram_state` when enabled.

    lat_hist[s, b]: requests from source s whose request latency (issue
    commit time minus emission time, cycles) fell in bin b. Bins are
    `lat_bin_width` cycles wide; the last bin is open-ended.
    """
    if not cfg.qos_enabled:
        return {}
    return {"lat_hist": jnp.zeros((cfg.n_src, cfg.lat_bins), jnp.int32)}


def bin_upper_edges(cfg: SimConfig) -> np.ndarray:
    """Host-side upper edge (cycles) of each histogram bin."""
    return (np.arange(cfg.lat_bins, dtype=np.float64) + 1.0) \
        * cfg.lat_bin_width


def on_issue(cfg: SimConfig, hist: jax.Array, src: jax.Array,
             lat: jax.Array, do_issue: jax.Array) -> jax.Array:
    """hist[src[c], bin(lat[c])] += 1 where do_issue[c]; all args (C,).

    One-hot masked accumulation over (C, S, BINS); duplicate sources
    across channels accumulate, matching scatter-add.
    """
    b = jnp.clip(lat // cfg.lat_bin_width, 0, cfg.lat_bins - 1)
    onehot = (jnp.arange(cfg.n_src)[None, :, None] == src[:, None, None]) \
        & (jnp.arange(cfg.lat_bins)[None, None, :] == b[:, None, None]) \
        & do_issue[:, None, None]
    return hist + jnp.sum(onehot.astype(hist.dtype), axis=0)
