"""GPipe-style pipeline parallelism over a mesh axis (default: ``pod``).

The pod axis has the lowest bisection bandwidth of the production mesh and
pipeline parallelism the lowest communication volume per step (one activation
handoff per microbatch per stage boundary), so stages map onto pods.
Fill-drain schedule: T = n_micro + n_stages - 1 ticks; stage handoff is a
single ``ppermute`` (point-to-point, no collective fan-in).

``gpipe_apply`` is schedule-only (activations); the backward pass comes from
differentiating through it — JAX reverses the ppermutes automatically.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

PyTree = Any


def gpipe_apply(stage_fn: Callable[[PyTree, jax.Array], jax.Array],
                stage_params: PyTree, x: jax.Array, n_micro: int,
                mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run ``n_stages`` chained stages over microbatches of x.

    stage_params: leading axis = stage (sharded over `axis`);
    x: (batch, ...) with batch % n_micro == 0 (replicated over `axis`).
    Returns stage_{S-1}(...stage_0(x)) with the same shape as x.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro
    xm = x.reshape((n_micro, mb) + x.shape[1:])
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def shard_fn(params_local, xm_local):
        params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
        sidx = jax.lax.axis_index(axis)
        zero = jnp.zeros_like(xm_local[0])
        T = n_micro + n_stages - 1

        def tick(carry, t):
            recv, outs = carry
            inp0 = jnp.where(t < n_micro,
                             xm_local[jnp.clip(t, 0, n_micro - 1)], zero)
            inp = jnp.where(sidx == 0, inp0, recv)
            h = stage_fn(params_local, inp)
            recv_next = jax.lax.ppermute(h, axis, perm)
            # last stage emits microbatch t-(n_stages-1)
            oidx = t - (n_stages - 1)
            valid = (sidx == n_stages - 1) & (oidx >= 0)
            outs = jax.lax.cond(
                oidx >= 0,
                lambda o: o.at[jnp.clip(oidx, 0, n_micro - 1)].set(
                    jnp.where(valid, h, o[jnp.clip(oidx, 0, n_micro - 1)])),
                lambda o: o, outs)
            return (recv_next, outs), None

        outs0 = jnp.zeros_like(xm_local)
        (_, outs), _ = jax.lax.scan(tick, (zero, outs0), jnp.arange(T))
        # only the last stage holds real outputs; broadcast via psum
        outs = jnp.where(sidx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    other = [a for a in mesh.axis_names if a != axis]
    # params: stage axis sharded; x: replicated over `axis`
    pspec = jax.tree_util.tree_map(
        lambda a: P(*([axis] + [None] * (a.ndim - 1))), stage_params)
    out = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, xm)
    return out.reshape(x.shape)


def split_layers_to_stages(stacked_params: PyTree, n_stages: int) -> PyTree:
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages}"
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree_util.tree_map(re, stacked_params)
