"""Sharding rules: logical param/activation axes -> mesh axes.

The production mesh is fixed by the assignment: single-pod ``(data=16,
model=16)``, multi-pod ``(pod=2, data=16, model=16)``. Per-arch rules resolve
which logical axes can legally map onto ``model`` (divisibility) and fall back
to replication otherwise — e.g. gemma2 has 8 q-heads < 16-way TP, so its
attention params replicate over ``model`` while MLP/vocab stay sharded (see
DESIGN.md §5 and the §Perf hillclimb for the batch-reshard alternative).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig

PyTree = Any


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Mesh axes the batch dim is sharded over (pod+data when divisible)."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    total = math.prod(mesh_axis_size(mesh, a) for a in axes)
    if axes and global_batch % total == 0:
        return tuple(axes)
    if "data" in mesh.axis_names and \
            global_batch % mesh_axis_size(mesh, "data") == 0:
        return ("data",)
    return ()


def axis_rules(cfg: ModelConfig, mesh: Mesh) -> Dict[Optional[str], Any]:
    tp = mesh_axis_size(mesh, "model")
    div = lambda n: n and n % tp == 0
    d_inner = cfg.ssm_expand * cfg.d_model
    rules: Dict[Optional[str], Any] = {
        None: None,
        "layers": None,
        "embed": None,
        "head_dim": None,
        "vocab": "model" if div(cfg.vocab_size) else None,
        "mlp": "model" if div(cfg.d_ff or cfg.moe_d_ff) else None,
        "experts": "model" if div(cfg.n_experts) else None,
        "heads": "model" if div(cfg.n_heads) else None,
        "kv_heads": "model" if div(cfg.n_kv_heads) else None,
        # ssm inner dim: sharded for the hybrid (hymba) family; the tiny
        # xlstm-125m replicates its cell (see DESIGN.md §5)
        "inner": "model" if (cfg.family == "hybrid" and div(d_inner)) else None,
    }
    return rules


def param_shardings(axes_tree: PyTree, cfg: ModelConfig, mesh: Mesh) -> PyTree:
    rules = axis_rules(cfg, mesh)

    def to_sharding(axes: Tuple[Optional[str], ...]) -> NamedSharding:
        # a mesh axis may appear once per spec: first logical axis wins
        # (e.g. MoE expert weights (experts, embed, mlp): `experts` takes
        # `model`; the per-expert mlp dim stays local)
        spec, used = [], set()
        for a in axes:
            m = rules.get(a)
            if m is not None and m in used:
                m = None
            if m is not None:
                used.add(m)
            spec.append(m)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(
        to_sharding, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and
        all(isinstance(e, (str, type(None))) for e in x))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> PyTree:
    """Shardings for the train-batch dict (tokens/labels/stub embeddings)."""
    bspec = batch_axes(mesh, shape.global_batch)
    b = bspec if bspec else None
    tok = NamedSharding(mesh, P(b, None))
    out = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        out["image_embeds"] = NamedSharding(mesh, P(b, None, None))
    if cfg.family == "audio":
        out["audio_embeds"] = NamedSharding(mesh, P(b, None, None))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> PyTree:
    """Shardings for the decode cache, per DESIGN.md §5.

    * kv heads sharded over ``model`` when divisible;
    * otherwise the *sequence* dim of the cache is sharded over ``model``
      (flash-decoding style: decode softmax/contract collectives are tiny);
    * batch over (pod, data) when divisible; batch==1 additionally pushes the
      sequence dim onto ``data``.
    """
    rules = axis_rules(cfg, mesh)
    bspec = batch_axes(mesh, shape.global_batch)
    b = bspec if bspec else None
    kv = rules["kv_heads"]
    seq_axes = []
    if kv is None:
        seq_axes.append("model")
    if not bspec and "data" in mesh.axis_names and \
            shape.seq_len % (mesh_axis_size(mesh, "data") *
                             mesh_axis_size(mesh, "model")) == 0:
        seq_axes.insert(0, "data")
    seq = tuple(seq_axes) if seq_axes else None
    kv_sh = NamedSharding(mesh, P(None, b, seq, kv, None))

    if cfg.family == "ssm":
        # xlstm: list of per-layer state tuples, replicated (tiny model)
        def sh(x):
            return NamedSharding(mesh, P(*([None] * len(x.shape))))
        from repro.models import xlstm as xlstm_lib
        cache = xlstm_lib.init_cache(cfg, shape.global_batch, shape.seq_len,
                                     abstract=True)
        return jax.tree_util.tree_map(sh, cache)
    if cfg.family == "hybrid":
        inner = rules["inner"]
        return {"k": kv_sh, "v": kv_sh,
                "ssm": NamedSharding(mesh, P(None, b, inner, None)),
                "conv": NamedSharding(mesh, P(None, b, None, inner))}
    if cfg.family == "audio":
        cross = NamedSharding(mesh, P(None, b, None, kv, None))
        return {"k": kv_sh, "v": kv_sh, "ck": cross, "cv": cross}
    return {"k": kv_sh, "v": kv_sh}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
