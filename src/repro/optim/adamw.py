"""AdamW + gradient clipping + schedules, in plain JAX pytrees.

(No optax in this environment — the optimizer is part of the substrate.)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array      # ()
    mu: PyTree           # first moment
    nu: PyTree           # second moment


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(grads: PyTree, state: AdamWState, params: PyTree, *,
           lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
           eps: float = 1e-8, weight_decay: float = 0.1
           ) -> Tuple[PyTree, AdamWState]:
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m.astype(v.dtype), v

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    m_leaves = treedef.flatten_up_to(state.mu)
    v_leaves = treedef.flatten_up_to(state.nu)
    p_leaves = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    unflat = lambda i: jax.tree_util.tree_unflatten(treedef,
                                                    [t[i] for t in out])
    return unflat(0), AdamWState(step, unflat(1), unflat(2))


def cosine_schedule(step: jax.Array, *, base_lr: float, warmup: int,
                    total: int, min_ratio: float = 0.1) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
