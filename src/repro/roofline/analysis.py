"""Three-term roofline model from compiled dry-run artifacts.

TPU v5e-class hardware constants (per the assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

  compute term    = HLO_FLOPs / peak_FLOPs            (per-chip, seconds)
  memory term     = HLO_bytes / HBM_bw                (per-chip, seconds)
  collective term = collective_bytes / link_bw        (per-chip, seconds)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (already
per-partition under SPMD). collective_bytes is parsed from the partitioned
HLO text: the summed operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes / s / chip
ICI_BW = 50e9              # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g.  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(...)
#       ROOT %t = (f32[8]{0}, f32[8]{0}) all-to-all(...)
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes per collective kind from partitioned HLO."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = {"compute_s": "compute", "memory_s": "memory",
             "collective_s": "collective"}[dom]
    total = max(compute_s, memory_s, collective_s)
    terms.update({
        "bottleneck": bound,
        "step_time_lower_bound_s": total,
        # fraction of the step the *compute* roofline would occupy if the
        # dominant term were fully overlapped == achievable MFU bound
        "roofline_fraction": compute_s / total if total > 0 else 0.0,
    })
    return terms


def model_flops(n_params: int, n_tokens: int, kind: str = "train") -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params * n_tokens
