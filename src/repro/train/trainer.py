"""Production training loop: checkpoint/restart, straggler mitigation,
emergency save, deterministic data, metrics.

Fault-tolerance model (single-process container, multi-host semantics):
  * checkpoint every N steps (async, atomic) + emergency save on exception;
  * resume picks up step + data position bit-identically;
  * straggler detection: per-step wall time vs EMA watermark; a host
    consistently above `straggler_factor`x median is reported and (policy
    "exclude") dropped from the healthy set -> the run continues on the
    remaining hosts with re-balanced data shards (elastic restart path);
  * `HostDelayInjector` simulates slow/failed hosts for tests/examples.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models.registry import get_model
from repro.optim import adamw
from repro.train import steps as steps_lib


@dataclass
class StragglerPolicy:
    factor: float = 3.0          # x median step time
    patience: int = 3            # consecutive slow steps before action
    action: str = "report"       # "report" | "exclude"


@dataclass
class HostDelayInjector:
    """Simulated per-host extra step latency (seconds); tests/demo only."""
    delays: Dict[int, float] = field(default_factory=dict)
    fail_at: Dict[int, int] = field(default_factory=dict)   # host -> step

    def step_time(self, host: int, base: float, step: int) -> float:
        if host in self.fail_at and step >= self.fail_at[host]:
            return float("inf")
        return base + self.delays.get(host, 0.0)


@dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    err: Any
    step: int


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, mesh,
                 shape: ShapeConfig, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, n_hosts: int = 1,
                 straggler: StragglerPolicy = StragglerPolicy(),
                 injector: Optional[HostDelayInjector] = None):
        self.cfg, self.run, self.mesh, self.shape = cfg, run, mesh, shape
        self.bundle = get_model(cfg)
        self.ckpt_dir = Path(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.n_hosts = n_hosts
        self.healthy_hosts = list(range(n_hosts))
        self.straggler = straggler
        self.injector = injector
        self.slow_counts = [0] * n_hosts
        self.step_times: List[float] = []
        self.metrics_log: List[Dict[str, float]] = []
        self.events: List[str] = []

        step_fn, in_sh = steps_lib.build_train_step(cfg, run, mesh, shape)
        self._step = jax.jit(step_fn, in_shardings=in_sh,
                             donate_argnums=(0, 1, 2))
        self.data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=shape.seq_len,
                                   global_batch=shape.global_batch,
                                   seed=run.seed)

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0) -> TrainState:
        params = self.bundle.init(jax.random.PRNGKey(seed))
        opt = adamw.init(params)
        err = (jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if self.run.grad_compression == "topk" else jnp.zeros(()))
        return TrainState(params, opt, err, 0)

    def maybe_restore(self) -> Optional[TrainState]:
        if not self.ckpt_dir:
            return None
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None
        st = self.init_state()
        tree = {"params": st.params, "opt": st.opt, "err": st.err}
        restored, manifest = ckpt.restore(self.ckpt_dir, step, tree)
        self.events.append(f"restored step {step}")
        return TrainState(restored["params"], restored["opt"],
                          restored["err"], step)

    # -- straggler handling -------------------------------------------------
    def _host_step_times(self, base: float, step: int) -> List[float]:
        if self.injector is None:
            return [base] * len(self.healthy_hosts)
        return [self.injector.step_time(h, base, step)
                for h in self.healthy_hosts]

    def _check_stragglers(self, times: List[float], step: int) -> None:
        med = float(np.median([t for t in times if np.isfinite(t)]))
        for i, h in enumerate(list(self.healthy_hosts)):
            slow = (not np.isfinite(times[i])) or \
                times[i] > self.straggler.factor * max(med, 1e-9)
            idx = self.healthy_hosts.index(h)
            self.slow_counts[h] = self.slow_counts[h] + 1 if slow else 0
            if self.slow_counts[h] >= self.straggler.patience or \
                    not np.isfinite(times[i]):
                self.events.append(
                    f"step {step}: host {h} straggling "
                    f"({times[i]:.3f}s vs median {med:.3f}s)")
                if self.straggler.action == "exclude":
                    self.healthy_hosts.remove(h)
                    self.events.append(
                        f"step {step}: excluded host {h}; "
                        f"{len(self.healthy_hosts)} hosts remain; "
                        f"data re-balanced")
                self.slow_counts[h] = 0

    # -- loop ---------------------------------------------------------------
    def train(self, n_steps: int, state: Optional[TrainState] = None
              ) -> TrainState:
        state = state or self.maybe_restore() or self.init_state()
        try:
            for _ in range(n_steps):
                t0 = time.time()
                batch = synthetic_batch(self.data_cfg, state.step)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, err, metrics = self._step(
                    state.params, state.opt, state.err, batch,
                    jnp.int32(state.step))
                metrics = {k: float(v) for k, v in metrics.items()}
                state = TrainState(params, opt, err, state.step + 1)
                dt = time.time() - t0
                self.step_times.append(dt)
                host_times = self._host_step_times(dt, state.step)
                self._check_stragglers(host_times, state.step)
                metrics["step"] = state.step
                metrics["step_time_s"] = dt
                self.metrics_log.append(metrics)
                if self.ckpt_dir and state.step % self.ckpt_every == 0:
                    ckpt.save_async(
                        self.ckpt_dir, state.step,
                        {"params": state.params, "opt": state.opt,
                         "err": state.err}).join()
                    ckpt.prune_old(self.ckpt_dir)
        except Exception:
            if self.ckpt_dir:
                ckpt.save(self.ckpt_dir, state.step,
                          {"params": state.params, "opt": state.opt,
                           "err": state.err},
                          extra={"emergency": True})
                self.events.append(f"emergency checkpoint at {state.step}")
            raise
        return state
