"""Jittable train / prefill / decode steps with full sharding annotations.

``build_train_step`` returns (step_fn, in_shardings, out_shardings) suitable
both for real execution and for the AOT dry-run (.lower on ShapeDtypeStructs).
The train step is the full production step: loss, grad, clip, AdamW update,
optional microbatch gradient accumulation, optional top-k gradient
compression with error feedback, optional ZeRO-1 optimizer-state sharding.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.models.registry import ModelBundle, get_model, input_specs
from repro.optim import adamw

PyTree = Any


def _zero1_shardings(params_sh: PyTree, abstract: PyTree, mesh: Mesh) -> PyTree:
    """ZeRO-1: additionally shard optimizer moments over ``data`` along the
    largest dim that is unsharded and divisible."""
    data = shlib.mesh_axis_size(mesh, "data")

    def opt_sh(sh: NamedSharding, av) -> NamedSharding:
        spec = list(sh.spec) + [None] * (len(av.shape) - len(sh.spec))
        best, best_size = -1, 0
        for i, (s, n) in enumerate(zip(spec, av.shape)):
            if s is None and n % data == 0 and n > best_size:
                best, best_size = i, n
        if best >= 0 and best_size >= data:
            spec[best] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(opt_sh, params_sh, abstract)


def _topk_compress(g: jax.Array, err: jax.Array, ratio: float):
    """Top-k sparsification with error feedback. Returns (g_hat, new_err)."""
    if g.ndim < 2:
        return g, err
    acc = g.astype(jnp.float32) + err
    flat = acc.reshape(-1)
    k = max(1, int(flat.size * ratio))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    sent = jnp.where(mask, flat, 0.0)
    return sent.reshape(g.shape).astype(g.dtype), (flat - sent).reshape(g.shape)


def build_train_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                     shape: ShapeConfig):
    """Returns (train_step, in_shardings, donate_argnums).

    train_step(params, opt_state, err_state, batch, step)
      -> (params, opt_state, err_state, metrics)
    """
    bundle = get_model(cfg)
    baxes = shlib.batch_axes(mesh, shape.global_batch)
    use_compress = run.grad_compression == "topk"

    def loss_fn(params, batch):
        return bundle.train_loss(params, run, batch, mesh=mesh,
                                 batch_axes=baxes or ("data",))

    def train_step(params, opt_state, err_state, batch, step):
        if run.microbatch and run.microbatch < shape.global_batch:
            n_micro = shape.global_batch // run.microbatch

            def micro(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, run.microbatch) + x.shape[1:]),
                batch)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            loss = lsum / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if use_compress:
            flat_g, tdef = jax.tree_util.tree_flatten(grads)
            flat_e = tdef.flatten_up_to(err_state)
            comp = [_topk_compress(g, e, run.topk_ratio)
                    for g, e in zip(flat_g, flat_e)]
            grads = jax.tree_util.tree_unflatten(tdef, [c[0] for c in comp])
            err_state = jax.tree_util.tree_unflatten(tdef, [c[1] for c in comp])

        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = adamw.cosine_schedule(step, base_lr=run.lr,
                                   warmup=run.warmup_steps,
                                   total=run.total_steps)
        params, opt_state = adamw.update(grads, opt_state, params, lr=lr,
                                         weight_decay=run.weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, err_state, metrics

    # shardings
    p_sh = shlib.param_shardings(bundle.axes(), cfg, mesh)
    abstract = bundle.abstract_params()
    if run.zero1:
        opt_p_sh = _zero1_shardings(p_sh, abstract, mesh)
    else:
        opt_p_sh = p_sh
    opt_sh = adamw.AdamWState(step=shlib.replicated(mesh), mu=opt_p_sh,
                              nu=opt_p_sh)
    err_sh = p_sh if use_compress else jax.tree_util.tree_map(
        lambda _: shlib.replicated(mesh), jnp.zeros(()))
    b_sh = shlib.batch_shardings(cfg, mesh, shape)
    step_sh = shlib.replicated(mesh)
    in_shardings = (p_sh, opt_sh, p_sh if use_compress else step_sh,
                    b_sh, step_sh)
    return train_step, in_shardings


def build_prefill_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                       shape: ShapeConfig):
    """prefill_step(params, tokens[, extra]) -> (logits, cache, lengths)."""
    bundle = get_model(cfg)
    baxes = shlib.batch_axes(mesh, shape.global_batch)
    b = baxes if baxes else None

    def prefill_step(params, tokens, extra=None):
        if cfg.family == "ssm":
            cache = None
        else:
            seq = shape.seq_len
            cache = bundle.init_cache(shape.global_batch, seq)
        return bundle.prefill(params, run, cache, tokens,
                              mesh=mesh, batch_axes=baxes or ("data",),
                              extra=extra)

    p_sh = shlib.param_shardings(bundle.axes(), cfg, mesh)
    tok_sh = NamedSharding(mesh, P(b, None))
    in_sh = [p_sh, tok_sh]
    if cfg.family in ("vlm", "audio"):
        key = "image_embeds" if cfg.family == "vlm" else "audio_embeds"
        in_sh.append({key: NamedSharding(mesh, P(b, None, None))})
    return prefill_step, tuple(in_sh)


def build_decode_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                      shape: ShapeConfig):
    """serve_step(params, cache, token, pos) -> (logits, cache)."""
    bundle = get_model(cfg)
    baxes = shlib.batch_axes(mesh, shape.global_batch)
    b = baxes if baxes else None

    def serve_step(params, cache, token, pos):
        return bundle.decode_step(params, run, cache, token, pos,
                                  mesh=mesh, batch_axes=baxes or ("data",))

    p_sh = shlib.param_shardings(bundle.axes(), cfg, mesh)
    c_sh = shlib.cache_shardings(cfg, mesh, shape)
    tok_sh = NamedSharding(mesh, P(b))
    return serve_step, (p_sh, c_sh, tok_sh, tok_sh)
