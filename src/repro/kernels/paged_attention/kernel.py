"""Paged decode attention Pallas-TPU kernel.

One new-token query per sequence attends over a paged KV cache:

  k_pages/v_pages : (n_pages_total, Hkv, page_size, d)   — the page pool
  page_table      : (B, max_pages)  int32                — scalar-prefetched
  lengths         : (B,)            int32                — valid tokens/seq

Grid: (B, Hkv, max_pages); the page axis is innermost and reduces into VMEM
scratch. The page table is scalar-prefetched so the BlockSpec index map can
stream exactly the pages each sequence owns HBM->VMEM (pages shared between
sequences — e.g. SMS stage-1 prefix-local batches — hit the same blocks).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, page_size: int, n_slots: int,
            scale: float, softcap: float):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lengths_ref[b]
    start = i * page_size

    @pl.when(start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (g, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, page)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(i == n_slots - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    softcap: float = 0.0,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, d); pages: (P, Hkv, page, d); page_table: (B, n_slots).

    Returns (B, Hq, d).
    """
    B, Hq, d = q.shape
    P, Hkv, page_size, _ = k_pages.shape
    g = Hq // Hkv
    assert g * Hkv == Hq
    n_slots = page_table.shape[1]
    qr = q.reshape(B, Hkv, g, d)

    kernel = functools.partial(_kernel, page_size=page_size, n_slots=n_slots,
                               scale=1.0 / math.sqrt(d), softcap=softcap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_slots),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h, i, pt, ln: (pt[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, h, i, pt, ln: (pt[b, i], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, h, i, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, d), q.dtype),
        interpret=interpret,
    )(page_table, lengths, qr, k_pages, v_pages)
    return out.reshape(B, Hq, d)
