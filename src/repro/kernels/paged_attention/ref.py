"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths,
                        softcap: float = 0.0):
    """q: (B, Hq, d); pages: (P, Hkv, page, d); page_table: (B, n_slots)."""
    B, Hq, d = q.shape
    P, Hkv, page, _ = k_pages.shape
    g = Hq // Hkv
    n_slots = page_table.shape[1]
    # gather each sequence's pages into contiguous (B, Hkv, S, d)
    k = k_pages[page_table]                       # (B, n_slots, Hkv, page, d)
    v = v_pages[page_table]
    k = k.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, n_slots * page, d)
    v = v.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, n_slots * page, d)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(n_slots * page)[None, None, :]
    s = jnp.where(pos < lengths[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
