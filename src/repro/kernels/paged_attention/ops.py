"""Jitted public wrapper: picks interpret mode off-TPU automatically."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel


import functools


@functools.partial(jax.jit, static_argnames=("softcap",))
def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    softcap: float = 0.0):
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k_pages, v_pages, page_table, lengths,
                   softcap=softcap, interpret=interpret)
