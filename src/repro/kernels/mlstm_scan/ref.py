"""Oracle: the validated XLA chunkwise mLSTM from the model library."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.xlstm import mlstm_chunk_scan


def mlstm_scan_ref(q, k, v, lf, li, chunk: int = 128):
    """q,k,v: (B,H,S,dh); lf,li: (B,H,S). Zero initial state."""
    B, H, S, dh = q.shape
    s0 = (jnp.zeros((B, H, dh, dh), jnp.float32),
          jnp.zeros((B, H, dh), jnp.float32),
          jnp.full((B, H), -40.0, jnp.float32))
    h, _ = mlstm_chunk_scan(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32),
                            lf.astype(jnp.float32), li.astype(jnp.float32),
                            s0, chunk=chunk)
    return h.astype(q.dtype)
