"""Jitted public wrapper: picks interpret mode off-TPU automatically."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_scan.kernel import mlstm_scan as _kernel


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, lf, li, *, chunk: int = 128):
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k, v, lf, li, chunk=chunk, interpret=interpret)
