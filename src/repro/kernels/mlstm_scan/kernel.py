"""Chunkwise-parallel mLSTM Pallas-TPU kernel (xLSTM's hot op).

Grid: (B*H, n_chunks) — the chunk axis is innermost and sequential; the
stabilized matrix-memory state (C̄ (dh,dh), n̄ (dh), m ()) lives in VMEM
scratch across chunk steps. Within a chunk everything is a masked
(chunk x chunk) matmul — MXU work — exactly the linear-time formulation
`repro.models.xlstm.mlstm_chunk_scan` uses in XLA.

VMEM working set per step at chunk=128, dh=384:
q,k,v 3·128·384·4 + C 384²·4 + D 128²·4 ≈ 1.4 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
MMIN = -40.0


def _kernel(q_ref, k_ref, v_ref, lf_ref, li_ref, h_ref,
            c_ref, n_ref, m_ref, *, chunk: int, dh: int, n_chunks: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, MMIN)

    q = q_ref[0].astype(jnp.float32) * (dh ** -0.5)      # (L, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lf = lf_ref[0].astype(jnp.float32)                   # (L,)
    li = li_ref[0].astype(jnp.float32)
    C, n, m = c_ref[...], n_ref[...], m_ref[0]

    F = jnp.cumsum(lf)                                   # (L,)
    dlog = F[:, None] - F[None, :] + li[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dlog = jnp.where(row >= col, dlog, NEG)
    state_log = F + m                                    # (L,)
    m_i = jnp.maximum(jnp.max(dlog, axis=1), state_log)
    m_i = jnp.maximum(m_i, MMIN)
    w = jnp.exp(dlog - m_i[:, None])                     # (L, L)
    sqk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (L, L)
    wsqk = w * sqk
    num = jax.lax.dot_general(wsqk, v, (((1,), (0,)), ((), ())))
    den = jnp.sum(wsqk, axis=1)
    sfac = jnp.exp(state_log - m_i)                      # (L,)
    num = num + sfac[:, None] * jax.lax.dot_general(
        q, C, (((1,), (0,)), ((), ())))
    den = den + sfac * jnp.sum(q * n[None, :], axis=1)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, None]
    h_ref[0] = h.astype(h_ref.dtype)

    # end-of-chunk state
    FL = F[chunk - 1]
    m_new = jnp.maximum(FL + m, jnp.max(FL - F + li))
    m_new = jnp.maximum(m_new, MMIN)
    wL = jnp.exp(FL - F + li - m_new)                    # (L,)
    c_ref[...] = jnp.exp(FL + m - m_new) * C + jax.lax.dot_general(
        k * wL[:, None], v, (((0,), (0,)), ((), ())))
    n_ref[...] = jnp.exp(FL + m - m_new) * n + jnp.sum(k * wL[:, None],
                                                       axis=0)
    m_ref[0] = m_new


def mlstm_scan(q, k, v, lf, li, *, chunk: int = 128,
               interpret: bool = False):
    """q,k,v: (B,H,S,dh); lf,li: (B,H,S) log-forget/log-input gates.

    Returns h: (B,H,S,dh) (fresh zero state; for decode-state threading use
    the XLA path in repro.models.xlstm).
    """
    B, H, S, dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, f"S={S} must divide chunk={chunk}"
    n_chunks = S // chunk
    qr = q.reshape(B * H, S, dh)
    kr = k.reshape(B * H, S, dh)
    vr = v.reshape(B * H, S, dh)
    lfr = lf.reshape(B * H, S)
    lir = li.reshape(B * H, S)

    kernel = functools.partial(_kernel, chunk=chunk, dh=dh,
                               n_chunks=n_chunks)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk), lambda b, i: (b, i)),
            pl.BlockSpec((1, chunk), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, lfr, lir)
    return out.reshape(B, H, S, dh)
