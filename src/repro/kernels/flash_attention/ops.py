"""Jitted public wrapper: picks interpret mode off-TPU automatically."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    block_q=128, block_k=128):
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k, v, causal=causal, window=window, softcap=softcap,
                   block_q=block_q, block_k=block_k, interpret=interpret)
