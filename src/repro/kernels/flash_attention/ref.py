"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d)."""
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
