"""Flash attention forward Pallas-TPU kernel.

Grid: (B*Hq, Sq/block_q, Sk/block_k) — the k axis is innermost and reduces
into VMEM scratch (acc, row-max, row-sum) that persists across sequential
grid steps; the output block is written on the final k step. Supports
causal, sliding-window and logit softcap (gemma2). GQA maps q-head blocks
onto kv heads in the BlockSpec index map (no materialized kv expansion —
this is the bandwidth win over the XLA path).

Block shapes are MXU-aligned (128x128 default); VMEM working set per step:
q (bq,d) + k,v (bk,d) + acc (bq,d) fp32 ~ 0.25 MB at d=128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            block_q: int, block_k: int, n_k_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window

    # skip fully-masked blocks (outside the causal / window band)
    band = jnp.any(mask)

    @pl.when(band)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d). Returns (B, Hq, Sq, d)."""
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    assert g * Hkv == Hq
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, \
        f"seq ({Sq},{Sk}) must divide blocks ({block_q},{block_k})"
    n_k = Sk // block_k
    qr = q.reshape(B * Hq, Sq, d)
    kr = k.reshape(B * Hkv, Sk, d)
    vr = v.reshape(B * Hkv, Sk, d)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k_blocks=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, d)
