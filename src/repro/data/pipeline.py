"""Deterministic, shardable synthetic token pipeline.

Every (step, global-row) cell is generated from a counter-based hash, so:
  * any data-parallel host can materialize exactly its rows (no broadcast),
  * restarts resume mid-stream bit-identically (fault tolerance),
  * elastic re-sharding (different host counts) yields the same global batch.

A real deployment swaps `synthetic_batch` for a tokenized corpus reader with
the same (step, row) -> example contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _hash2(a: np.ndarray, b: np.ndarray, seed: int) -> np.ndarray:
    """64-bit mix of two uint64 arrays (splitmix-style)."""
    x = (a * np.uint64(0x9E3779B97F4A7C15) ^
         (b + np.uint64(seed) * np.uint64(0xBF58476D1CE4E5B9)))
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return x


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-ish structure so the loss actually decreases
    n_patterns: int = 64
    pattern_len: int = 16


def synthetic_batch(cfg: DataConfig, step: int, row_start: int = 0,
                    n_rows: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Rows [row_start, row_start+n_rows) of step's global batch.

    Tokens follow repeated vocab patterns with hash-seeded phase, giving a
    learnable distribution (bigram structure) rather than iid noise.
    """
    n_rows = cfg.global_batch if n_rows is None else n_rows
    rows = (np.arange(row_start, row_start + n_rows, dtype=np.uint64)
            + np.uint64(step) * np.uint64(cfg.global_batch))
    pos = np.arange(cfg.seq_len + 1, dtype=np.uint64)
    h = _hash2(rows[:, None], pos[None, :] // np.uint64(cfg.pattern_len),
               cfg.seed)
    pattern = (h % np.uint64(cfg.n_patterns)).astype(np.int64)
    phase = pos[None, :] % np.uint64(cfg.pattern_len)
    toks = (pattern * cfg.pattern_len + phase.astype(np.int64)) \
        % max(cfg.vocab_size - 2, 1) + 1
    noise = _hash2(rows[:, None], pos[None, :], cfg.seed + 1)
    flip = (noise % np.uint64(100)) < np.uint64(3)      # 3% noise tokens
    toks = np.where(flip, (noise % np.uint64(cfg.vocab_size)).astype(np.int64),
                    toks)
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_iterator(cfg: DataConfig, host_id: int, n_hosts: int,
                  start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """This host's shard of each step (contiguous row block)."""
    assert cfg.global_batch % n_hosts == 0
    per = cfg.global_batch // n_hosts
    step = start_step
    while True:
        yield synthetic_batch(cfg, step, row_start=host_id * per, n_rows=per)
        step += 1


def batch_checksum(batch: Dict[str, np.ndarray]) -> int:
    return int(sum(int(v.astype(np.int64).sum()) for v in batch.values()))
