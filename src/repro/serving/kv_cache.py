"""Ref-counted paged KV allocator (host control plane).

Pages are the serving analogue of DRAM rows: shared-prefix pages are
allocated once and ref-counted across requests; per-request tail pages are
private. The device-side pools live as (L, P, Hkv, page, d) arrays owned by
the engine; this allocator only manages page indices.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple


class PagedAllocator:
    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = n_pages
        self.page_size = page_size
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = [0] * n_pages
        self.prefix_pages: Dict[int, List[int]] = {}   # prefix_id -> pages

    # -- raw pages ---------------------------------------------------------
    def alloc_page(self) -> Optional[int]:
        if not self.free:
            return None
        p = self.free.pop()
        self.refcount[p] = 1
        return p

    def ref(self, page: int) -> None:
        assert self.refcount[page] > 0
        self.refcount[page] += 1

    def unref(self, page: int) -> None:
        assert self.refcount[page] > 0
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self.free.append(page)

    @property
    def n_free(self) -> int:
        return len(self.free)

    # -- sequences ---------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc_seq(self, total_len: int, prefix_id: Optional[int] = None,
                  prefix_len: int = 0) -> Optional[Tuple[List[int], int]]:
        """Allocate pages for a sequence; shared-prefix pages are reused.

        Returns (pages, n_shared_pages) or None if out of pages.
        """
        shared: List[int] = []
        n_full_shared = 0
        if prefix_id is not None and prefix_len >= self.page_size:
            n_full_shared = prefix_len // self.page_size
            existing = self.prefix_pages.get(prefix_id)
            if existing is not None and len(existing) >= n_full_shared:
                shared = existing[:n_full_shared]
                for p in shared:
                    self.ref(p)
            else:
                # rebuilding (longer prefix): release the old pin first
                if existing is not None:
                    del self.prefix_pages[prefix_id]
                    for p in existing:
                        self.unref(p)
                shared = []
                for _ in range(n_full_shared):
                    p = self.alloc_page()
                    if p is None:
                        for q in shared:
                            self.unref(q)
                        return None
                    shared.append(p)
                # pin the prefix (one standing ref held by the table)
                for p in shared:
                    self.ref(p)
                self.prefix_pages[prefix_id] = shared
        n_priv = self.pages_needed(total_len) - len(shared)
        priv: List[int] = []
        for _ in range(max(n_priv, 0)):
            p = self.alloc_page()
            if p is None:
                for q in priv:
                    self.unref(q)
                for q in shared:
                    self.unref(q)
                return None
            priv.append(p)
        return shared + priv, len(shared)

    def extend_seq(self, pages: List[int], old_len: int, new_len: int
                   ) -> bool:
        """Grow a sequence; allocates new tail pages as needed."""
        need = self.pages_needed(new_len) - len(pages)
        for _ in range(max(need, 0)):
            p = self.alloc_page()
            if p is None:
                return False
            pages.append(p)
        return True

    def free_seq(self, pages: List[int]) -> None:
        for p in pages:
            self.unref(p)

    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_pages
