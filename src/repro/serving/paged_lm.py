"""Dense GQA LM running over a paged KV pool with the Pallas kernel.

The real-model backend of the serving engine: decode reads/writes the
(L, P, Hkv, page, d) page pools through page tables, attention runs the
``repro.kernels.paged_attention`` kernel (interpret mode off-TPU).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.kernels.paged_attention.kernel import paged_attention
from repro.models import lm as lm_lib
from repro.models.common import apply_rope, rms_norm, softcap


def init_pools(cfg: ModelConfig, n_pages: int, page_size: int,
               dtype=jnp.float32) -> Dict[str, jax.Array]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, n_pages, cfg.n_kv_heads, page_size, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


@functools.partial(jax.jit, static_argnames=("cfg", "run", "page_size"))
def paged_decode_step(params, cfg: ModelConfig, run: RunConfig,
                      pools, token, pos, page_table, *, page_size: int):
    """token/pos: (B,); page_table: (B, n_slots). Returns (logits, pools).

    pos is the index of the *new* token; attention covers [0, pos].
    """
    assert not cfg.parallel_block, "paged_lm: sequential blocks only"
    B = token.shape[0]
    interp = jax.default_backend() != "tpu"
    x = params["lm"]["embed"][token[:, None]].astype(run.compute_dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pid = page_table[jnp.arange(B), pos // page_size]     # (B,)
    off = pos % page_size
    lengths = pos + 1
    windows = lm_lib.layer_windows(cfg)
    new_k, new_v = pools["k"], pools["v"]
    for li in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[li], params["blocks"])
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"].astype(h.dtype))
        if cfg.qkv_bias:
            q = q + p["attn"]["bq"].astype(h.dtype)
            k = k + p["attn"]["bk"].astype(h.dtype)
            v = v + p["attn"]["bv"].astype(h.dtype)
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        # write the new token's k/v into the pools
        new_k = new_k.at[li, pid, :, off, :].set(
            k[:, 0].astype(new_k.dtype))
        new_v = new_v.at[li, pid, :, off, :].set(
            v[:, 0].astype(new_v.dtype))
        a = paged_attention(q[:, 0].astype(jnp.float32),
                            new_k[li].astype(jnp.float32),
                            new_v[li].astype(jnp.float32),
                            page_table, lengths, softcap=cfg.attn_softcap,
                            interpret=interp)
        a = a[:, None].astype(h.dtype)
        attn_out = jnp.einsum("bshk,hkd->bsd",
                              a.reshape(B, 1, cfg.n_heads, -1),
                              p["attn"]["wo"].astype(h.dtype))
        if cfg.post_norm:
            attn_out = rms_norm(attn_out, p["pn1"], cfg.norm_eps)
        x = x + attn_out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        m = lm_lib._mlp_apply(p["mlp"], cfg, h2)
        if cfg.post_norm:
            m = rms_norm(m, p["pn2"], cfg.norm_eps)
        x = x + m
    x = rms_norm(x, params["lm"]["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["lm"]["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm"]["lm_head"].astype(x.dtype))
    return softcap(logits[:, 0], cfg.logit_softcap), \
        {"k": new_k, "v": new_v}
