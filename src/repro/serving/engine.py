"""Continuous-batching engine with SMS-staged admission.

Two backends share the control plane:
  * CostModelBackend — step latency from a calibrated cost model
    (ms = c0 + c_tok·tokens + c_page·distinct_pages). Used by the scheduling
    benchmarks: page-distinctness is exactly what stage-1 locality batching
    optimizes (shared-prefix pages are counted once per step — the "row hit").
  * Real backend (examples/tests) — repro.serving.paged_lm running an actual
    tiny model over the paged pool with the Pallas paged-attention kernel.

The engine admits from the scheduler under slot/page budgets, chunk-prefills,
then decodes one token per running sequence per step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.serving.kv_cache import PagedAllocator
from repro.serving.scheduler import SCHEDULERS, SchedulerBase
from repro.serving.types import ClientSpec, Request


@dataclass
class RunningSeq:
    req: Request
    pages: List[int]
    target_len: int          # prompt + max_new
    cur_len: int = 0         # tokens materialized in KV
    n_shared: int = 0


@dataclass
class EngineConfig:
    page_size: int = 16
    n_pages: int = 4096
    max_slots: int = 32
    prefill_budget: int = 256       # prompt tokens per step
    # cost model (ms)
    c0: float = 0.5
    c_tok: float = 0.004
    c_page: float = 0.010


@dataclass
class StepStats:
    prefill_tokens: int = 0
    decode_tokens: int = 0
    distinct_pages: int = 0


class ServingEngine:
    def __init__(self, cfg: EngineConfig, scheduler: SchedulerBase,
                 seed: int = 0):
        self.cfg = cfg
        self.sched = scheduler
        self.alloc = PagedAllocator(cfg.n_pages, cfg.page_size)
        self.running: List[RunningSeq] = []
        self.now = 0.0
        self.finished: List[Request] = []
        self.steps = 0
        self.rng = np.random.RandomState(seed)

    # -- admission -----------------------------------------------------
    def _try_admit(self) -> None:
        while len(self.running) < self.cfg.max_slots:
            req = self.sched.pop_admission(self.now)
            if req is None:
                return
            total = req.prompt_len + req.max_new
            prefix_id = req.prefix_id if req.prefix_id >= 0 else None  # <0: private
            got = self.alloc.alloc_seq(total, prefix_id,
                                       prefix_len=min(req.prompt_len,
                                                      self._prefix_len(req)))
            if got is None:
                # out of pages: put it back at the head (engine backpressure)
                self.sched.admission.appendleft(req) if hasattr(
                    self.sched, "admission") else self.sched.enqueue(
                        req, self.now)
                return
            pages, n_shared = got
            req.admitted = self.now
            shared_tokens = n_shared * self.cfg.page_size
            self.running.append(RunningSeq(
                req, pages, total, cur_len=shared_tokens, n_shared=n_shared))
            req.prefilled = shared_tokens

    def _prefix_len(self, req: Request) -> int:
        return getattr(req, "shared_prefix_len", 0)

    # -- one engine iteration -------------------------------------------
    def step(self) -> StepStats:
        self._try_admit()
        st = StepStats()
        touched: Set[int] = set()
        budget = self.cfg.prefill_budget
        done: List[RunningSeq] = []
        for rs in self.running:
            if rs.cur_len < rs.req.prompt_len and budget > 0:
                chunk = min(budget, rs.req.prompt_len - rs.cur_len)
                lo, hi = rs.cur_len, rs.cur_len + chunk
                touched.update(rs.pages[lo // self.cfg.page_size:
                                        -(-hi // self.cfg.page_size)])
                rs.cur_len = hi
                rs.req.prefilled = hi
                st.prefill_tokens += chunk
                budget -= chunk
        for rs in self.running:
            if rs.cur_len >= rs.req.prompt_len:
                # decode one token: reads all of the sequence's pages
                touched.update(rs.pages[: -(-rs.cur_len //
                                            self.cfg.page_size)])
                rs.cur_len += 1
                rs.req.generated += 1
                st.decode_tokens += 1
                if rs.req.first_token is None:
                    rs.req.first_token = self.now
                if rs.req.done:
                    done.append(rs)
        st.distinct_pages = len(touched)
        dt = self.cfg.c0 + self.cfg.c_tok * (
            st.prefill_tokens + st.decode_tokens) + \
            self.cfg.c_page * st.distinct_pages
        self.now += dt
        self.steps += 1
        for rs in done:
            rs.req.finished = self.now
            self.alloc.free_seq(rs.pages)
            self.sched.on_finish(rs.req)
            self.finished.append(rs.req)
            self.running.remove(rs)
        return st


# ---------------------------------------------------------------------------
# workload generation + driver
# ---------------------------------------------------------------------------

def generate_requests(clients: List[ClientSpec], horizon_ms: float,
                      seed: int = 0) -> List[Request]:
    rng = np.random.RandomState(seed)
    out: List[Request] = []
    rid = 0
    for ci, spec in enumerate(clients):
        if spec.kind == "interactive":
            t = float(rng.exponential(spec.rate_ms))
            while t < horizon_ms:
                # unique (non-shared) prefix per interactive request
                r = Request(rid, ci, prefix_id=-(rid + 1),
                            prompt_len=spec.prompt_len, max_new=spec.max_new,
                            arrival=t)
                r.shared_prefix_len = 0
                out.append(r)
                rid += 1
                t += float(rng.exponential(spec.rate_ms))
        else:
            for k in range(spec.n_queued):
                pfx = 10_000 * (ci + 1) + (k % spec.n_prefixes)
                r = Request(rid, ci, prefix_id=pfx,
                            prompt_len=spec.prompt_len, max_new=spec.max_new,
                            arrival=0.0)
                r.shared_prefix_len = spec.shared_prefix_len
                out.append(r)
                rid += 1
    out.sort(key=lambda r: r.arrival)
    return out


def run_serving(policy: str, clients: List[ClientSpec],
                horizon_ms: float = 8_000.0, engine_cfg: EngineConfig = None,
                active: Optional[Set[int]] = None, seed: int = 0,
                max_steps: int = 200_000) -> Dict:
    """Run one policy; `active` restricts to a client subset (alone runs)."""
    engine_cfg = engine_cfg or EngineConfig()
    sched = SCHEDULERS.get(policy)(len(clients), seed=seed)
    eng = ServingEngine(engine_cfg, sched, seed=seed)
    reqs = generate_requests(clients, horizon_ms, seed=seed)
    if active is not None:
        reqs = [r for r in reqs if r.client in active]
    i = 0
    while eng.steps < max_steps:
        while i < len(reqs) and reqs[i].arrival <= eng.now:
            sched.enqueue(reqs[i], eng.now)
            i += 1
        if i >= len(reqs) and not eng.running and sched.queued() == 0:
            break
        if eng.now > horizon_ms * 4:        # runaway guard
            break
        eng.step()

    per_client: Dict[int, List[Request]] = {}
    for r in eng.finished:
        per_client.setdefault(r.client, []).append(r)
    stats = {}
    for ci, spec in enumerate(clients):
        rs = per_client.get(ci, [])
        if not rs:
            continue
        lat = np.array([r.latency for r in rs])
        ttft = np.array([(r.first_token - r.arrival) for r in rs
                         if r.first_token is not None])
        stats[spec.name] = {
            "n": len(rs),
            "mean_latency_ms": float(lat.mean()),
            "p99_latency_ms": float(np.percentile(lat, 99)),
            "mean_ttft_ms": float(ttft.mean()) if len(ttft) else None,
            "throughput_tok_s": float(sum(r.generated for r in rs)
                                      / max(eng.now / 1e3, 1e-9)),
        }
    return {
        "policy": policy,
        "clients": stats,
        "total_finished": len(eng.finished),
        "elapsed_ms": eng.now,
        "engine_steps": eng.steps,
        "total_tok_s": float(sum(r.generated for r in eng.finished)
                             / max(eng.now / 1e3, 1e-9)),
    }


def fairness_report(policy: str, clients: List[ClientSpec],
                    horizon_ms: float = 8_000.0,
                    engine_cfg: EngineConfig = None, seed: int = 0) -> Dict:
    """Shared run + per-client alone runs -> slowdowns (paper's metric)."""
    shared = run_serving(policy, clients, horizon_ms, engine_cfg, seed=seed)
    slowdowns = {}
    for ci, spec in enumerate(clients):
        alone = run_serving(policy, clients, horizon_ms, engine_cfg,
                            active={ci}, seed=seed)
        a = alone["clients"].get(spec.name)
        s = shared["clients"].get(spec.name)
        if a and s:
            slowdowns[spec.name] = s["mean_latency_ms"] / \
                max(a["mean_latency_ms"], 1e-9)
    shared["slowdowns"] = slowdowns
    shared["max_slowdown"] = max(slowdowns.values()) if slowdowns else None
    return shared
