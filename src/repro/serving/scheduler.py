"""SMS-staged request scheduler for the serving engine + baselines.

Stage 1 (batch formation): one FIFO per client; consecutive requests hitting
the same shared prefix ("row") form a batch; ready on prefix-change, age
threshold, or full FIFO.

Stage 2 (batch scheduler): among ready batches pick SJF (client with fewest
in-flight requests across all stages) with probability p, else round-robin;
drain the picked batch into stage 3.

Stage 3 (admission FIFO): per-engine FIFO the continuous-batching engine pops
under its token/page budget — the analogue of the DCS issuing under DRAM
timing constraints.

Baselines: FCFS (single global queue) and LOCALITY-FIRST (FR-FCFS analogue:
always prefer requests whose prefix pages are already hot).

Schedulers register with `SCHEDULERS`, an instance of the same
`repro.core.policy.Registry` the cycle sim's memory policies use, so both
domains enumerate and resolve policies through one mechanism:

    @SCHEDULERS.register
    class MyScheduler(SchedulerBase):
        name = "mine"
        ...
"""
from __future__ import annotations

import collections
import functools
import random
from typing import Deque, Dict, List, Optional

from repro.core.policy import Registry
from repro.serving.types import Request

SCHEDULERS = Registry("serving scheduler")


class SchedulerBase:
    name = "base"

    def __init__(self, n_clients: int, seed: int = 0):
        self.n_clients = n_clients
        self.seed = seed

    def enqueue(self, req: Request, now: float) -> None:
        raise NotImplementedError

    def pop_admission(self, now: float) -> Optional[Request]:
        """Next request to admit into the running batch (or None)."""
        raise NotImplementedError

    def on_finish(self, req: Request) -> None:
        pass

    def queued(self) -> int:
        raise NotImplementedError


@SCHEDULERS.register
class FCFSScheduler(SchedulerBase):
    """Single global arrival-ordered queue (no client awareness)."""

    name = "fcfs"

    def __init__(self, n_clients: int, seed: int = 0):
        super().__init__(n_clients, seed)
        self.q: Deque[Request] = collections.deque()

    def enqueue(self, req, now):
        self.q.append(req)

    def pop_admission(self, now):
        return self.q.popleft() if self.q else None

    def queued(self):
        return len(self.q)


@SCHEDULERS.register
class LocalityFirstScheduler(SchedulerBase):
    """FR-FCFS analogue: requests hitting the currently-open prefix first,
    then oldest. Maximizes page reuse; starves low-locality clients."""

    name = "locality"

    def __init__(self, n_clients: int, seed: int = 0):
        super().__init__(n_clients, seed)
        self.q: List[Request] = []
        self.open_prefix: Optional[int] = None

    def enqueue(self, req, now):
        self.q.append(req)

    def pop_admission(self, now):
        if not self.q:
            return None
        hit = [r for r in self.q if r.prefix_id == self.open_prefix]
        pick = min(hit, key=lambda r: r.arrival) if hit else \
            min(self.q, key=lambda r: r.arrival)
        self.q.remove(pick)
        self.open_prefix = pick.prefix_id
        return pick

    def queued(self):
        return len(self.q)


@SCHEDULERS.register
class SMSScheduler(SchedulerBase):
    """The paper's three stages on serving requests.

    ``adaptive_p`` (beyond paper, from its §5 p-sensitivity study): a
    feedback controller replaces the static SJF probability — when the
    longest-waiting head-of-FIFO belongs to a light (latency-sensitive)
    client, p rises toward SJF; when a heavy client's queue stalls, p falls
    toward round-robin. Bounded to [p_min, p_max].
    """

    name = "sms"

    def __init__(self, n_clients: int, fifo_size: int = 16,
                 age_cap_ms: float = 10.0, sjf_prob: float = 0.9,
                 admission_depth: int = 64, seed: int = 0,
                 adaptive_p: bool = False, p_min: float = 0.5,
                 p_max: float = 0.98, wait_target_ms: float = 30.0):
        super().__init__(n_clients, seed)
        self.fifos: List[Deque[Request]] = [collections.deque()
                                            for _ in range(n_clients)]
        self.fifo_size = fifo_size
        self.age_cap = age_cap_ms
        self.p = sjf_prob
        self.admission: Deque[Request] = collections.deque()
        self.admission_depth = admission_depth
        self.rr = 0
        self.rng = random.Random(seed)
        self.inflight = [0] * n_clients     # across all stages + running
        self.adaptive_p = adaptive_p
        self.p_min, self.p_max = p_min, p_max
        self.wait_target = wait_target_ms
        self.p_trace: List[float] = []

    def _adapt(self, now: float) -> None:
        """One controller step per batch pick."""
        waits = [(now - f[0].arrival, c) for c, f in enumerate(self.fifos)
                 if f]
        if not waits:
            return
        worst_wait, worst_client = max(waits)
        if worst_wait <= self.wait_target:
            return
        median_inflight = sorted(self.inflight)[self.n_clients // 2]
        if self.inflight[worst_client] <= median_inflight:
            self.p = min(self.p + 0.02, self.p_max)   # light client waiting
        else:
            self.p = max(self.p - 0.02, self.p_min)   # heavy client starving
        self.p_trace.append(self.p)

    def enqueue(self, req, now):
        self.fifos[req.client].append(req)
        self.inflight[req.client] += 1

    def _batch_len(self, c: int) -> int:
        f = self.fifos[c]
        if not f:
            return 0
        n, pfx = 0, f[0].prefix_id
        for r in f:
            if r.prefix_id != pfx:
                break
            n += 1
        return n

    def _ready(self, c: int, now: float) -> bool:
        f = self.fifos[c]
        if not f:
            return False
        blen = self._batch_len(c)
        return (blen < len(f)) or (now - f[0].arrival >= self.age_cap) \
            or (len(f) >= self.fifo_size)

    def _drain_one_batch(self, now: float) -> bool:
        ready = [c for c in range(self.n_clients) if self._ready(c, now)]
        if not ready:
            return False
        if self.adaptive_p:
            self._adapt(now)
        if self.rng.random() < self.p:                      # SJF
            pick = min(ready, key=lambda c: (self.inflight[c], c))
        else:                                               # round-robin
            pick = min(ready, key=lambda c: ((c - self.rr) % self.n_clients))
            self.rr = (pick + 1) % self.n_clients
        blen = self._batch_len(pick)
        for _ in range(blen):
            self.admission.append(self.fifos[pick].popleft())
        return True

    def pop_admission(self, now):
        while len(self.admission) < self.admission_depth:
            if not self._drain_one_batch(now):
                break
        return self.admission.popleft() if self.admission else None

    def on_finish(self, req):
        self.inflight[req.client] -= 1

    def queued(self):
        return len(self.admission) + sum(len(f) for f in self.fifos)


SCHEDULERS.register("sms_adaptive")(
    functools.partial(SMSScheduler, adaptive_p=True, sjf_prob=0.7))

# registers the utilization-aware admission-control policy ("admission");
# bottom import so its SchedulerBase/SCHEDULERS imports resolve
from repro.serving import admission as _admission  # noqa: E402,F401
