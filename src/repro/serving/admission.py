"""Utilization-aware admission control for the serving engine.

Port of the GPU-scheduler admission-control ideas (ROADMAP item 2): a
moving-average utilization tracker with spike detection and a cooldown
window, as a registered serving `SCHEDULERS` policy. The scheduler
estimates each request's cost as its total token footprint
(prompt + max_new), tracks the in-flight total against a capacity, and:

  * admits lightest-first while the *effective* load — in-flight plus the
    candidate's cost scaled by a safety headroom — stays under
    ``threshold`` of capacity (admit-below-threshold);
  * maintains an exponential moving average of utilization and flags a
    spike when instantaneous utilization exceeds ``spike_ratio`` times
    the average AND jumped by more than ``spike_jump`` in one observation
    (a burst the average hasn't caught up with — the jump term keeps a
    gradual self-induced ramp from idle out of the detector);
  * on a spike, enters a cooldown window during which nothing is
    admitted, letting the running batch drain before taking more load.

Queued work is never dropped — admission is deferred, not refused — so
request conservation holds (everything is admitted once load allows).
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

from repro.serving.scheduler import SCHEDULERS, SchedulerBase
from repro.serving.types import Request


def request_cost(req: Request) -> int:
    """Token-footprint estimate: KV pages + compute both scale with it."""
    return req.prompt_len + req.max_new


@SCHEDULERS.register
class AdmissionControlScheduler(SchedulerBase):
    name = "admission"

    def __init__(self, n_clients: int, seed: int = 0,
                 capacity_tokens: int = 8192, threshold: float = 0.85,
                 headroom: float = 1.1, ema_alpha: float = 0.1,
                 spike_ratio: float = 1.5, spike_jump: float = 0.25,
                 util_floor: float = 0.2, cooldown_ms: float = 25.0):
        super().__init__(n_clients, seed)
        self.capacity = float(capacity_tokens)
        self.threshold = threshold
        self.headroom = headroom
        self.ema_alpha = ema_alpha
        self.spike_ratio = spike_ratio
        self.spike_jump = spike_jump
        self.util_floor = util_floor
        self.cooldown = cooldown_ms
        # lightest-first admission order; arrival then rid break ties so
        # equal-cost requests stay FCFS and the heap never compares Requests
        self.q: List[Tuple[int, float, int, Request]] = []
        self.inflight_tokens = 0
        self.util_ema = 0.0
        self.cooldown_until = -1.0
        self.spikes = 0
        self.util_trace: List[float] = []

    # -- utilization tracking ----------------------------------------------
    def _utilization(self) -> float:
        return self.inflight_tokens / self.capacity

    def _observe(self, now: float) -> float:
        """One tracker step: update the moving average, detect a spike."""
        util = self._utilization()
        prev = self.util_ema
        self.util_ema = (1.0 - self.ema_alpha) * prev + self.ema_alpha * util
        self.util_trace.append(util)
        if now >= self.cooldown_until \
                and util - prev > self.spike_jump \
                and util > self.spike_ratio * max(prev, self.util_floor):
            self.spikes += 1
            self.cooldown_until = now + self.cooldown
        return util

    # -- SchedulerBase protocol --------------------------------------------
    def enqueue(self, req: Request, now: float) -> None:
        heapq.heappush(self.q, (request_cost(req), req.arrival, req.rid, req))

    def pop_admission(self, now: float) -> Optional[Request]:
        util = self._observe(now)
        if not self.q or now < self.cooldown_until:
            return None
        cost, _, _, req = self.q[0]
        effective = util + (cost * self.headroom) / self.capacity
        if effective > self.threshold:
            return None
        heapq.heappop(self.q)
        self.inflight_tokens += cost
        return req

    def on_finish(self, req: Request) -> None:
        self.inflight_tokens -= request_cost(req)

    def queued(self) -> int:
        return len(self.q)
