"""Serving request/client types for the SMS-as-LLM-scheduler adaptation.

Mapping from the paper (DESIGN.md §2):
  DRAM row       <-> shared prefix block (KV pages reused across requests)
  CPU core       <-> interactive client (few outstanding, latency-sensitive)
  GPU            <-> bulk client (deep queue, heavy shared-prefix locality)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Request:
    rid: int
    client: int
    prefix_id: int              # "row address": which shared prefix it hits
    prompt_len: int
    max_new: int
    arrival: float              # engine time (ms)
    # lifecycle
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    generated: int = 0
    prefilled: int = 0

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new

    @property
    def latency(self) -> float:
        return (self.finished - self.arrival) if self.finished else float("inf")


@dataclass
class ClientSpec:
    name: str
    kind: str                   # "interactive" | "bulk"
    rate_ms: float              # mean inter-arrival (interactive)
    n_queued: int               # initial queue depth (bulk)
    prompt_len: int
    shared_prefix_len: int      # tokens served from shared prefix pages
    max_new: int
    n_prefixes: int             # distinct prefixes the client cycles over


def default_clients() -> List[ClientSpec]:
    return [
        ClientSpec("chat0", "interactive", 40.0, 0, 96, 0, 24, 1 << 30),
        ClientSpec("chat1", "interactive", 55.0, 0, 64, 0, 24, 1 << 30),
        ClientSpec("chat2", "interactive", 70.0, 0, 128, 0, 32, 1 << 30),
        ClientSpec("chat3", "interactive", 90.0, 0, 80, 0, 16, 1 << 30),
        # bulk batch-inference tenant: deep queue, strong prefix locality
        ClientSpec("bulk", "bulk", 0.0, 600, 544, 512, 24, 3),
    ]
