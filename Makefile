# Tier-1 verification entry points. `make ci` is what the GitHub Actions
# workflow runs: dev deps + the full suite + a simulation-speed smoke run
# (tiny cycle counts — catches trace-size/compile-time regressions),
# fail-fast.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test ci deps-dev quickstart bench-smoke bench-simspeed bench-qos \
	bench-dse bench-timeline bench-trend check-invariants

deps-dev:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

# SMOKE_OUT: optional path for a JSON run summary (CI uploads it as an
# artifact), e.g. `make bench-smoke SMOKE_OUT=bench-smoke-summary.json`
bench-smoke:
	$(PY) -m benchmarks.simspeed --smoke \
		$(if $(SMOKE_OUT),--summary-out $(SMOKE_OUT))
	$(PY) -m benchmarks.fig_pareto --smoke

bench-simspeed:
	$(PY) -m benchmarks.simspeed

# self-check gate: every policy runs with the invariant sanitizer armed
# (ticked + variable-step + stacked) and must stay violation-free, then
# every registered fault is injected and must be CAUGHT.
# INVARIANTS_OUT: optional path for the violation-summary JSON artifact.
check-invariants:
	$(PY) -m benchmarks.check_invariants \
		$(if $(INVARIANTS_OUT),--out $(INVARIANTS_OUT))

# 3-class (CPU+GPU+HWA) QoS family: per-class deadline-met rate, tail
# latency, and class-masked fairness across every registry policy
bench-qos:
	$(PY) -m benchmarks.run --only qos

# design-space exploration: the (policy x knob-variant) grid as ONE stacked
# XLA program, scored into the energy/perf/area Pareto frontier
bench-dse:
	$(PY) -m benchmarks.fig_pareto

# flight-recorder figure: per-epoch interference timelines on a GPU-bursty
# 3-class mix; --check asserts SMS's relative CPU-latency spike stays
# below the best centralized policy's (the paper's smoothing claim)
bench-timeline:
	$(PY) -m benchmarks.fig_timeline --check

# perf-trend ledger: gate the committed BENCH_simspeed.json snapshot
# against BENCH_history.jsonl, then record it as a new ledger entry
bench-trend:
	$(PY) -m benchmarks.bench_trend --check --append

ci: deps-dev test

quickstart:
	$(PY) examples/quickstart.py
