# Tier-1 verification entry points. `make ci` is what the GitHub Actions
# workflow runs: dev deps + the full suite, fail-fast.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test ci deps-dev quickstart

deps-dev:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

ci: deps-dev test

quickstart:
	$(PY) examples/quickstart.py
