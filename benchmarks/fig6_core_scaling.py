"""Fig 6: SMS vs TCM as the number of CPU cores scales (memory pressure)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl

CORE_COUNTS = (4, 8, 12, 16)
HI_CATS = ("HL", "HML", "HM", "H")


def main(n_per_cat: int = 7, n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    print("# Fig 6 — SMS vs TCM, core scaling "
          "(WS gain % / fairness x, high-intensity workloads)")
    print("n_cpu,tcm_ws,sms_ws,ws_gain_pct,tcm_maxsd,sms_maxsd,fairness_x")
    rows = []
    for n_cpu in CORE_COUNTS:
        cfg = common.parity_config(n_cpu=n_cpu, n_channels=4)  # paper: 4 MCs
        wls = [w for w in wl.make_workloads(n_cpu, n_per_cat=n_per_cat)
               if w.category in HI_CATS]
        res = common.run_sweep(cfg, ("tcm", "sms"), wls, n_cycles=n_cycles,
                               tag=f"fig6_c{n_cpu}", force=force)
        t, s = res["tcm"]["agg"], res["sms"]["agg"]
        gain = 100 * (s["weighted_speedup"] / t["weighted_speedup"] - 1)
        fx = t["max_slowdown"] / s["max_slowdown"]
        print(f"{n_cpu},{t['weighted_speedup']:.3f},{s['weighted_speedup']:.3f},"
              f"{gain:.1f},{t['max_slowdown']:.2f},{s['max_slowdown']:.2f},"
              f"{fx:.2f}")
        rows.append((n_cpu, gain, fx))
    us = (time.time() - t0) * 1e6 / max(len(CORE_COUNTS), 1)
    trend = "increasing" if rows[-1][1] >= rows[0][1] else "flat"
    common.emit("fig6_core_scaling", us,
                f"gain_4c={rows[0][1]:.1f}%;gain_16c={rows[-1][1]:.1f}%;"
                f"trend={trend};paper=gains_grow_with_cores")
    return rows


if __name__ == "__main__":
    main()
