"""§5.2: power/area structure-count proxy, at the paper's scale
(16 CPU + 1 GPU, 4 MCs, ~300 entries per MC, entry parity)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import power
from repro.core.params import SimConfig


def main(force: bool = False):
    t0 = time.time()
    cfg = common.parity_config(n_cpu=16, n_channels=4, fifo_size=15,
                               dcs_size=6)
    c = power.compare(cfg)
    print("# Power/area proxy (relative units, entry parity "
          f"{c['frfcfs_entries']:.0f} vs {c['sms_entries']:.0f})")
    for k in ("frfcfs_area", "sms_area", "frfcfs_leakage", "sms_leakage"):
        print(f"{k},{c[k]:.0f}")
    us = (time.time() - t0) * 1e6
    common.emit("power_area", us,
                f"area_reduction_pct={c['area_reduction_pct']:.1f};"
                f"leakage_reduction_pct={c['leakage_reduction_pct']:.1f};"
                f"paper=46.3%/66.7%")
    return c


if __name__ == "__main__":
    main()
