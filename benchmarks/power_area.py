"""§5.2: power/area structure-count proxy + full-MC energy, at the paper's
scale (16 CPU + 1 GPU, 4 MCs, ~300 entries per MC, entry parity).

The static rows reproduce the paper's CAM-vs-FIFO area/leakage comparison;
the energy rows combine that static leakage with the measured dynamic DRAM
energy (`repro.core.energy` counters over a short shared-workload run)
into whole-MC nJ-per-request — the axis the "energy-efficient" claim
actually lives on.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import power
from repro.core import simulator as sim
from repro.core import workloads as wl

ENERGY_POLICIES = ("frfcfs", "sms")
ENERGY_CYCLES = 4_000
ENERGY_WARMUP = 500


def _combine(cfg, pol, m, n_workloads) -> Dict[str, float]:
    dyn = float((m["energy_act"] + m["energy_rw"]).sum())
    bg = float(m["energy_bg"].sum() + m["energy_wake"].sum())
    reqs = float(m["completed"].sum())
    return power.full_mc_energy(cfg, pol, dyn, bg,
                                ENERGY_CYCLES * n_workloads, reqs)


def dynamic_energy_rows(cfg, force: bool = False
                        ) -> Dict[str, Dict[str, float]]:
    """Full-MC energy per request for `ENERGY_POLICIES` on a tiny shared
    mix (2 workloads) at the §5.2 configuration.

    Raw sim metrics cache under EXP_DIR (config-determined only); the
    full-MC combine bakes in power.py constants so it is recomputed on
    every run — same contract as `benchmarks.fig_energy`.
    """
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=1)[:2]
    pool, active = wl.pool_batch(cfg, wls)
    out = {}
    todo = []
    for pol in ENERGY_POLICIES:
        key = common._key(cfg, pol, "power_area", ENERGY_CYCLES,
                          ENERGY_WARMUP, 7, len(wls))
        path = common.EXP_DIR / f"energy_pa_{pol}_{key}.json"
        if path.exists() and not force:
            m = {k: np.asarray(v) for k, v in
                 json.loads(path.read_text()).items()}
            out[pol] = _combine(cfg, pol, m, len(wls))
        else:
            todo.append((pol, path))
    devs = [(pol, path, sim.simulate_async(cfg, pol, pool, active,
                                           ENERGY_CYCLES, ENERGY_WARMUP))
            for pol, path in todo]               # async: overlap compiles
    for pol, path, dev in devs:
        m = {k: np.asarray(v) for k, v in dev.items()}
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps({k: v.tolist() for k, v in m.items()},
                                   indent=1))
        out[pol] = _combine(cfg, pol, m, len(wls))
    return {pol: out[pol] for pol in ENERGY_POLICIES}


def main(force: bool = False):
    t0 = time.time()
    cfg = common.parity_config(n_cpu=16, n_channels=4, fifo_size=15,
                               dcs_size=6)
    c = power.compare(cfg)
    print("# Power/area proxy (relative units, entry parity "
          f"{c['frfcfs_entries']:.0f} vs {c['sms_entries']:.0f})")
    for k in ("frfcfs_area", "sms_area", "frfcfs_leakage", "sms_leakage"):
        print(f"{k},{c[k]:.0f}")
    e = dynamic_energy_rows(cfg, force=force)
    print("# Full-MC energy (static leakage + measured dynamic DRAM, nJ)")
    print("policy,nj_per_req,static_frac,dram_dynamic_nj,dram_background_nj")
    for pol, r in e.items():
        print(f"{pol},{r['energy_per_request_nj']:.2f},"
              f"{r['static_frac']:.3f},{r['dram_dynamic_nj']:.0f},"
              f"{r['dram_background_nj']:.0f}")
    us = (time.time() - t0) * 1e6
    fr, sm = (e[p]["energy_per_request_nj"] for p in ("frfcfs", "sms"))
    common.emit("power_area", us,
                f"area_reduction_pct={c['area_reduction_pct']:.1f};"
                f"leakage_reduction_pct={c['leakage_reduction_pct']:.1f};"
                f"energy_per_req_nj=frfcfs:{fr:.1f}/sms:{sm:.1f};"
                f"paper=46.3%/66.7%")
    return {**c, "energy": e}


if __name__ == "__main__":
    main()
