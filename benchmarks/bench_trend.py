"""Perf-trend ledger: append simspeed/smoke runs, flag regressions.

`BENCH_simspeed.json` is a two-point snapshot (baseline vs current); this
module keeps the full trajectory in `BENCH_history.jsonl` — one JSON object
per line per recorded run — so a slow drift is as visible as a cliff.

Entry schema (one line each):

    {"ts": "...", "kind": "simspeed" | "smoke", "label": "...",
     "sweep": {"cycles_per_s": ..., "wall_s": ..., ...},
     "scale": {"n_per_cat": ..., "n_cycles": ..., "warmup": ...},
     "meta": {"jax": ..., "backend": ...}}

Only entries at the SAME sweep scale are comparable — cycles/s at smoke
scale is dominated by compile time — so `--check` compares the candidate
against the best ledger entry with a matching `scale` and fails (exit 1)
when throughput drops by more than `--tolerance` (default 20%).

CLI:

    python -m benchmarks.bench_trend --check          # gate current repo
                                                      # snapshot vs ledger
    python -m benchmarks.bench_trend --append         # record the current
                                                      # BENCH_simspeed.json
    python -m benchmarks.bench_trend --append --summary out.json \
        --kind smoke                                  # record a smoke run

`make bench-trend` runs append+check; CI runs `--check` against the
committed ledger after `make bench-smoke`.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

ROOT = Path(__file__).resolve().parents[1]
LEDGER = ROOT / "BENCH_history.jsonl"
BENCH = ROOT / "BENCH_simspeed.json"


def load_ledger(path: Path = LEDGER) -> List[Dict]:
    """Parsed ledger entries; unparsable lines are skipped with a note on
    stderr (a corrupt line must not wedge the trend gate)."""
    if not path.exists():
        return []
    out = []
    for i, line in enumerate(path.read_text().splitlines()):
        line = line.strip()
        if not line:
            continue
        try:
            out.append(json.loads(line))
        except json.JSONDecodeError:
            print(f"bench_trend: skipping corrupt ledger line {i + 1}",
                  file=sys.stderr)
    return out


def append_entry(entry: Dict, path: Path = LEDGER) -> None:
    with path.open("a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")


def entry_from_summary(summary: Dict, kind: str = "simspeed",
                       label: str = "") -> Optional[Dict]:
    """Ledger entry from a simspeed --summary-out dict (or the `current`
    half of BENCH_simspeed.json). None when the summary has no sweep
    section (nothing comparable to record)."""
    sweep = summary.get("sweep")
    if not sweep:
        return None
    meta = summary.get("meta", {})
    return {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "kind": kind,
        "label": label,
        "sweep": {k: sweep[k] for k in
                  ("cycles_per_s", "wall_s", "n_workloads", "n_cycles",
                   "warmup") if k in sweep},
        "scale": meta.get("sweep_scale",
                          {"n_cycles": sweep.get("n_cycles"),
                           "warmup": sweep.get("warmup")}),
        "meta": {k: meta.get(k) for k in ("jax", "backend") if k in meta},
    }


def check(candidate: Dict, ledger: List[Dict],
          tolerance: float = 0.20) -> Tuple[bool, str]:
    """(ok, message): does `candidate` hold the ledger's recorded pace?

    Compares candidate sweep cycles/s against the BEST same-scale ledger
    entry; passes vacuously (with a note) when the ledger has no
    comparable entry — a scale change must not hard-fail CI.
    """
    cps = candidate.get("sweep", {}).get("cycles_per_s")
    if cps is None:
        return False, "candidate has no sweep.cycles_per_s"
    scale = candidate.get("scale")
    peers = [e for e in ledger
             if e.get("scale") == scale
             and e.get("sweep", {}).get("cycles_per_s")]
    if not peers:
        return True, (f"no ledger entry at scale {scale}; "
                      f"nothing to compare (pass)")
    best = max(peers, key=lambda e: e["sweep"]["cycles_per_s"])
    ref = best["sweep"]["cycles_per_s"]
    floor = ref * (1.0 - tolerance)
    ok = cps >= floor
    verdict = "OK" if ok else "REGRESSION"
    return ok, (f"{verdict}: {cps:.1f} cycles/s vs ledger best {ref:.1f} "
                f"({best['ts']}, {best.get('label') or best['kind']}); "
                f"floor at -{tolerance:.0%} is {floor:.1f}")


def candidate_from_bench(bench_path: Path = BENCH) -> Optional[Dict]:
    """The repo's committed snapshot (`current` half) as a ledger entry."""
    if not bench_path.exists():
        return None
    data = json.loads(bench_path.read_text())
    return entry_from_summary(data.get("current", {}), kind="simspeed",
                              label="BENCH_simspeed.json current")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--append", action="store_true",
                    help="append the candidate to the ledger")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on a throughput regression vs the "
                         "best same-scale ledger entry")
    ap.add_argument("--summary", type=Path, default=None,
                    help="read the candidate from a simspeed --summary-out "
                         "JSON instead of BENCH_simspeed.json")
    ap.add_argument("--kind", default=None,
                    help="entry kind for --append (default: simspeed, or "
                         "smoke when --summary is given)")
    ap.add_argument("--label", default="", help="free-form entry label")
    ap.add_argument("--ledger", type=Path, default=LEDGER)
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional throughput drop (default 0.2)")
    args = ap.parse_args(argv)
    if not (args.append or args.check):
        ap.error("nothing to do: pass --append and/or --check")

    if args.summary is not None:
        summary = json.loads(args.summary.read_text())
        cand = entry_from_summary(summary, kind=args.kind or "smoke",
                                  label=args.label or str(args.summary))
    else:
        cand = candidate_from_bench()
        if cand is not None and args.kind:
            cand["kind"] = args.kind
        if cand is not None and args.label:
            cand["label"] = args.label
    if cand is None:
        print("bench_trend: no sweep section in the candidate; nothing to "
              "record or check", file=sys.stderr)
        return 0 if args.check else 1

    rc = 0
    if args.check:
        ok, msg = check(cand, load_ledger(args.ledger),
                        tolerance=args.tolerance)
        print(f"bench_trend: {msg}")
        rc = 0 if ok else 1
    if args.append:
        append_entry(cand, args.ledger)
        print(f"bench_trend: appended {cand['kind']} entry to "
              f"{args.ledger.name}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
