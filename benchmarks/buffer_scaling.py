"""Request-buffer size scalability: TCM needs a large CAM buffer for
visibility; SMS at entry parity already wins (§3/§5 discussion)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl

SIZES = ((3, 2), (6, 4), (12, 8), (24, 16))   # (fifo, dcs) -> parity E
HI_CATS = ("HL", "HML", "HM", "H")


def main(n_per_cat: int = 7, n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    print("# Buffer scaling — TCM vs SMS at entry parity")
    print("entries_per_chan,tcm_ws,sms_ws,tcm_maxsd,sms_maxsd")
    rows = []
    for fifo, dcs in SIZES:
        cfg = common.parity_config(fifo_size=fifo, dcs_size=dcs)
        wls = [w for w in wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
               if w.category in HI_CATS]
        res = common.run_sweep(cfg, ("tcm", "sms"), wls, n_cycles=n_cycles,
                               tag=f"buf_{fifo}_{dcs}", force=force)
        t, s = res["tcm"]["agg"], res["sms"]["agg"]
        print(f"{cfg.buf_entries},{t['weighted_speedup']:.3f},"
              f"{s['weighted_speedup']:.3f},{t['max_slowdown']:.2f},"
              f"{s['max_slowdown']:.2f}")
        rows.append((cfg.buf_entries, s["weighted_speedup"],
                     t["weighted_speedup"]))
    us = (time.time() - t0) * 1e6 / max(len(SIZES), 1)
    common.emit("buffer_scaling", us,
                f"sms_small_buf_ws={rows[0][1]:.3f};"
                f"tcm_small_buf_ws={rows[0][2]:.3f};"
                f"paper=sms_wins_at_equal_entries")
    return rows


if __name__ == "__main__":
    main()
