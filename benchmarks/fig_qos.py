"""QoS benchmark family: CPU+GPU+HWA mixes with frame deadlines.

The N-class growth of `benchmarks/dash_deadline.py` (ROADMAP open item 3):
every registry policy runs a 3-class workload sweep — `n_hwa` SQUASH-style
frame-deadline accelerators (`workloads.HWA_BENCH`) next to the CPU cores
and the GPU — through the stacked `run_sweep` path, and is scored on the
QoS surface the 2-class benchmarks can't see:

  * `dl_met_rate` — frame-deadline-met rate for the HWA class (frames met
    over frames released in the measurement window);
  * `lat_p95_*` / `lat_p99_*` — per-class tail request latency (cycles),
    reduced from the issue-time latency histogram (`repro.core.qos`);
  * `cpu_max_slowdown` / `hwa_max_slowdown` — deadline-aware fairness: the
    shared max-slowdown reduction masked per class;
  * `urgent_admits` — how often `squash_prio`'s urgent tier jumped the
    admission queue (zero for policies without an urgent tier).

Output convention: ``fig_qos,us_per_call,derived`` CSV row after the table.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common
from repro.core import metrics as met
from repro.core import workloads as wl
from repro.core.params import CLS_HWA, SimConfig


def qos_config(n_cpu: int = 4, n_hwa: int = 2,
               n_channels: int = 2) -> SimConfig:
    """3-class parity config: fewer cores than the 2-class sweeps so the
    HWA frame bursts actually contend with the CPU/GPU streams."""
    return common.parity_config(n_cpu=n_cpu, n_channels=n_channels,
                                n_hwa=n_hwa)


COLUMNS = ("dl_met_rate", "lat_p99_cpu", "lat_p99_hwa", "cpu_max_slowdown",
           "hwa_max_slowdown", "weighted_speedup")


def main(n_per_cat: int = 4, n_cycles: int = 12_000,
         force: bool = False, strict: bool = False) -> dict:
    t0 = time.time()
    cfg = qos_config()
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat, seed=13,
                            n_hwa=cfg.n_hwa)
    policies = list(common.POLICIES)
    results = common.run_sweep(cfg, policies, wls, n_cycles=n_cycles,
                               tag="qos", force=force, strict=strict)

    hwa = met.class_vector(cfg) == CLS_HWA
    print("policy," + ",".join(COLUMNS) + ",urgent_admits")
    urgents = {}
    for pol, res in results.items():
        if "error" in res:
            print(f"{pol},ERROR:{res['error']}")
            continue
        ua = float(np.asarray(res["measured"].get(
            "urgent_admits", np.zeros(cfg.n_src)))[hwa].sum())
        urgents[pol] = ua
        vals = [res["agg"][c] for c in COLUMNS]
        print(pol + "," + ",".join(f"{v:.3f}" for v in vals) + f",{ua:.0f}")

    healthy = {p: r for p, r in results.items() if "error" not in r}
    best = max(healthy, key=lambda p: healthy[p]["agg"]["dl_met_rate"])
    us = (time.time() - t0) * 1e6 / max(len(policies), 1)
    common.emit(
        "fig_qos", us,
        f"best_met={best}:{results[best]['agg']['dl_met_rate']:.3f};"
        f"squash_urgent_admits={urgents.get('squash_prio', 0):.0f};"
        f"n_hwa={cfg.n_hwa}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--strict", dest="strict", action="store_true",
                    help="re-raise on the first failing sweep slice")
    ap.add_argument("--tolerant", dest="strict", action="store_false",
                    help="degrade failing slices and report the healthy "
                         "remainder (default)")
    ap.set_defaults(strict=False)
    args = ap.parse_args()
    main(force=args.force, strict=args.strict)
