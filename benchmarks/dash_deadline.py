"""SMS-DASH: deadline-aware scheduling for accelerators (paper §7).

The paper's future-work section says SMS's principles extend to real-time
accelerators (Usui et al. SQUASH/DASH built exactly that). This bench adds a
frame-deadline accelerator (dl_reqs requests / dl_period cycles) to the
CPU+GPU mix and compares deadline hit-rate + CPU cost across schedulers.
SMS-DASH = SMS with least-slack-first preemption in stage 2.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import power
from repro.core import simulator as sim
from repro.core.params import SimConfig

# squash_prio belongs here: its probabilistic boost is deadline-aware
POLICIES = ("frfcfs", "tcm", "bliss", "squash_prio", "sms", "sms_dash")


def build(n_channels: int = 2):
    cfg = SimConfig(n_cpu=4, n_gpu=2, n_channels=n_channels, buf_entries=72,
                    fifo_size=8, dcs_size=4)
    mpki = np.array([30, 38, 25, 33, 1000, 1000], np.float32)
    pool = {
        "mpki": mpki, "inst_per_miss": np.maximum(1000 / mpki, 1),
        "rbl": np.array([.5, .45, .6, .55, .9, .85], np.float32),
        "blp": np.array([3, 4, 2, 5, 4, 4], np.int32),
        "is_gpu": np.array([0, 0, 0, 0, 1, 0], bool),
        "dl_period": np.array([0, 0, 0, 0, 0, 1000], np.int32),
        "dl_reqs": np.array([0, 0, 0, 0, 0, 45], np.int32),
    }
    return cfg, {k: v[None] for k, v in pool.items()}


def main(n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    cfg, pb = build()
    active = np.ones((1, cfg.n_src), bool)
    print("# SMS-DASH — frame deadlines (45 reqs / 1000 cycles) vs CPU cost")
    print("policy,frames_met,frames_total,cpu_ipc,gpu_bw,nj_per_req")
    results = {}
    for pol in POLICIES:
        m = sim.simulate(cfg, pol, pb, active, n_cycles, 2_000)
        met = int(m["dl_met"][0, 5])
        total = met + int(m["dl_missed"][0, 5])
        cpu = float(m["ipc"][0, :4].mean())
        # full-MC energy per request: measured DRAM dynamic + background
        # energy combined with this scheduler's structure leakage
        e = power.full_mc_energy(
            cfg, pol, float((m["energy_act"] + m["energy_rw"]).sum()),
            float(m["energy_bg"].sum() + m["energy_wake"].sum()),
            n_cycles, float(m["completed"].sum()))
        results[pol] = (met, total, cpu, e["energy_per_request_nj"])
        print(f"{pol},{met},{total},{cpu:.3f},{float(m['bw'][0, 4]):.3f},"
              f"{e['energy_per_request_nj']:.2f}")
    us = (time.time() - t0) * 1e6 / len(POLICIES)
    dash_met, total, dash_cpu, dash_nj = results["sms_dash"]
    sms_met, _, sms_cpu, sms_nj = results["sms"]
    common.emit("dash_deadline", us,
                f"dash_met={dash_met}/{total};sms_met={sms_met}/{total};"
                f"cpu_cost_pct={100 * (1 - dash_cpu / sms_cpu):.1f};"
                f"nj_per_req=dash:{dash_nj:.1f}/sms:{sms_nj:.1f};"
                f"paper_s7=sms_extends_to_deadline_scheduling")
    return results


if __name__ == "__main__":
    main()
