"""Fig 7: SMS vs TCM as memory channels scale (1..8)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl

CHANNELS = (1, 2, 4, 8)
HI_CATS = ("HL", "HML", "HM", "H")


def main(n_per_cat: int = 7, n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    print("# Fig 7 — SMS vs TCM, channel scaling (high-intensity workloads)")
    print("channels,tcm_ws,sms_ws,ws_gain_pct,tcm_maxsd,sms_maxsd,fairness_x")
    rows = []
    for nc in CHANNELS:
        cfg = common.parity_config(n_channels=nc)
        wls = [w for w in wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
               if w.category in HI_CATS]
        res = common.run_sweep(cfg, ("tcm", "sms"), wls, n_cycles=n_cycles,
                               tag=f"fig7_ch{nc}", force=force)
        t, s = res["tcm"]["agg"], res["sms"]["agg"]
        gain = 100 * (s["weighted_speedup"] / t["weighted_speedup"] - 1)
        fx = t["max_slowdown"] / s["max_slowdown"]
        print(f"{nc},{t['weighted_speedup']:.3f},{s['weighted_speedup']:.3f},"
              f"{gain:.1f},{t['max_slowdown']:.2f},{s['max_slowdown']:.2f},"
              f"{fx:.2f}")
        rows.append((nc, gain, s["weighted_speedup"], t["weighted_speedup"]))
    us = (time.time() - t0) * 1e6 / max(len(CHANNELS), 1)
    sms_scale = rows[-1][2] / max(rows[0][2], 1e-9)
    tcm_scale = rows[-1][3] / max(rows[0][3], 1e-9)
    common.emit("fig7_channel_scaling", us,
                f"sms_8ch_vs_1ch_x={sms_scale:.2f};tcm_8ch_vs_1ch_x="
                f"{tcm_scale:.2f};paper=sms_scales_better")
    return rows


if __name__ == "__main__":
    main()
