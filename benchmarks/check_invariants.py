"""Self-check gate: run every registered policy with the invariant
sanitizer armed and fail loudly on any violation.

Two halves, both required for the gate to mean anything:

  1. CLEAN: every policy in `sim.ALL_POLICIES` runs ticked AND under the
     variable-step driver (plus the stackable family on the stacked path,
     both modes) with `validate_enabled=True`; every violation counter
     must stay zero.
  2. ARMED: one registered fault per violation family is injected and
     MUST be caught — a sanitizer that cannot flag a known-bad run is
     reported as a failure, not a pass.

Writes a violation-summary JSON (per-run counter breakdown, uploaded as a
CI artifact via ``make check-invariants``) and exits nonzero on any clean
violation or any undetected fault.

Output convention: ``check_invariants,us_per_call,derived`` CSV row.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks import common
from repro.core import faults, validate
from repro.core import simulator as sim
from repro.core import workloads as wl


def _check_pool(cfg):
    """One representative 3-class workload row (deterministic)."""
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=1, seed=13,
                            n_hwa=cfg.n_hwa)
    pool, active = wl.pool_batch(cfg, wls[:1])
    return ({k: np.asarray(v)[0] for k, v in pool.items()},
            np.asarray(active)[0])


def main(n_cycles: int = 1_200, out: str = None) -> int:
    t0 = time.time()
    cfg = common.parity_config(n_cpu=4, n_hwa=1).replace(
        validate_enabled=True)
    pool, active = _check_pool(cfg)
    report = {"cache_version": common.CACHE_VERSION, "n_cycles": n_cycles,
              "clean": {}, "faults": {}, "failures": []}

    def record(section, name, summary, expect_zero, targets=()):
        nz = {k: int(v) for k, v in summary.items() if v}
        report[section][name] = nz
        if expect_zero and nz:
            report["failures"].append(f"{name}: unexpected violations {nz}")
        if not expect_zero and not sum(summary[k] for k in targets):
            report["failures"].append(
                f"{name}: fault NOT caught (targets {targets}, "
                f"counters {nz})")

    # -- clean runs: all policies, ticked + skip ---------------------------
    for pol in sim.ALL_POLICIES:
        for skip in (False, True):
            st = sim.simulate_debug(cfg, pol, pool, active,
                                    n_cycles=n_cycles, skip=skip)
            record("clean", f"{pol}/{'skip' if skip else 'tick'}",
                   validate.summarize(np.asarray(st[2]["viol"])), True)
    stackable = sim.stackable_names(cfg)
    for skip in (False, True):
        out_st = sim.simulate_debug_stacked(cfg, stackable, pool, active,
                                            n_cycles=n_cycles, skip=skip)
        for pol, (_, _, dram) in out_st.items():
            record("clean",
                   f"stacked/{pol}/{'skip' if skip else 'tick'}",
                   validate.summarize(np.asarray(dram["viol"])), True)

    # -- armed runs: every registered fault must be detected ---------------
    idle = dict(pool)
    idle["mpki"] = np.full_like(pool["mpki"], 0.5)
    for name in faults.FAULTS:
        targets = faults.TARGETS[name]
        skip = name in faults.SKIP_ONLY
        p = idle if skip else pool
        with faults.inject(name):
            if name in faults.STACKED_ONLY:
                outs = sim.simulate_debug_stacked(
                    cfg, ("frfcfs", "parbs"), p, active,
                    n_cycles=n_cycles, skip=False)
                summary = validate.summarize(
                    np.asarray(outs["parbs"][2]["viol"]))
            else:
                st = sim.simulate_debug(cfg, "frfcfs", p, active,
                                        n_cycles=n_cycles, skip=skip)
                summary = validate.summarize(np.asarray(st[2]["viol"]))
        record("faults", name, summary, False, targets)

    ok = not report["failures"]
    report["ok"] = ok
    if out:
        Path(out).parent.mkdir(parents=True, exist_ok=True)
        Path(out).write_text(json.dumps(report, indent=1))
    for f in report["failures"]:
        print(f"FAIL: {f}", file=sys.stderr)
    n_runs = len(report["clean"]) + len(report["faults"])
    common.emit(
        "check_invariants", (time.time() - t0) * 1e6 / max(n_runs, 1),
        f"clean_runs={len(report['clean'])};faults={len(report['faults'])};"
        f"failures={len(report['failures'])};"
        f"gate={'pass' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-cycles", type=int, default=1_200)
    ap.add_argument("--out", type=str, default=None,
                    help="write the violation-summary JSON here")
    args = ap.parse_args()
    sys.exit(main(n_cycles=args.n_cycles, out=args.out))
