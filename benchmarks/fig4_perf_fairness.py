"""Fig 4: system performance (weighted speedup) + fairness (max slowdown)
across the 7 workload categories, 105 workloads, and every policy in the
registry (`simulator.ALL_POLICIES`) — the paper's 5 schedulers plus the
registered extensions (sms_dash, bliss, squash_prio)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl


def main(n_per_cat: int = 15, n_cycles: int = 16_000, force: bool = False):
    cfg = common.parity_config()
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    t0 = time.time()
    results = common.run_sweep(cfg, common.POLICIES, wls, n_cycles=n_cycles,
                               tag="fig4", force=force)
    us = (time.time() - t0) * 1e6 / max(len(wls) * len(common.POLICIES), 1)

    print("# Fig 4a — weighted speedup by category")
    print(common.fmt_cat_table(results, "weighted_speedup"))
    print("# Fig 4b — max slowdown by category (lower is better)")
    print(common.fmt_cat_table(results, "max_slowdown"))
    sms, tcm = results["sms"]["agg"], results["tcm"]["agg"]
    ws_gain = 100.0 * (sms["weighted_speedup"] / tcm["weighted_speedup"] - 1)
    fair_gain = tcm["max_slowdown"] / sms["max_slowdown"]
    common.emit("fig4_sms_vs_tcm", us,
                f"ws_gain_pct={ws_gain:.1f};fairness_x={fair_gain:.2f};"
                f"paper=+41.2%/4.8x")
    return results


if __name__ == "__main__":
    main()
