"""Serving-domain SMS benchmark (beyond-paper adaptation).

Heterogeneous clients — 4 interactive (CPU-analogue) + 1 bulk tenant with
deep queues and shared-prefix locality (GPU-analogue) — share one
continuous-batching engine. Compares FCFS, locality-first (FR-FCFS
analogue), and SMS staged scheduling on throughput and per-client slowdown.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.serving.engine import EngineConfig, fairness_report
from repro.serving.scheduler import SCHEDULERS
from repro.serving.types import default_clients

# same enumeration mechanism as the cycle sim: the scheduler registry
POLICIES = SCHEDULERS.names()


def main(quick: bool = False):
    horizon = 2_000.0 if quick else 6_000.0
    t0 = time.time()
    clients = default_clients()
    results = {}
    print("# Serving: per-client slowdown vs isolated run (lower is better)")
    print("policy,max_slowdown,total_tok_s," +
          ",".join(c.name for c in clients))
    for pol in POLICIES:
        r = fairness_report(pol, clients, horizon_ms=horizon,
                            engine_cfg=EngineConfig())
        results[pol] = r
        sd = [r["slowdowns"].get(c.name, float("nan")) for c in clients]
        print(f"{pol},{r['max_slowdown']:.2f},{r['total_tok_s']:.0f}," +
              ",".join(f"{s:.2f}" for s in sd))
    us = (time.time() - t0) * 1e6 / len(POLICIES)
    fx_fcfs = results["fcfs"]["max_slowdown"] / results["sms"]["max_slowdown"]
    fx_loc = results["locality"]["max_slowdown"] / \
        results["sms"]["max_slowdown"]
    thr = results["sms"]["total_tok_s"] / max(
        results["locality"]["total_tok_s"], 1e-9)
    common.emit("serving_sms", us,
                f"fairness_vs_fcfs_x={fx_fcfs:.1f};"
                f"fairness_vs_locality_x={fx_loc:.1f};"
                f"throughput_ratio={thr:.3f}")
    return results


if __name__ == "__main__":
    main()
