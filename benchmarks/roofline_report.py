"""§Roofline: the full (arch x shape) table from the dry-run artifacts.

Reads experiments/dryrun/*.json (single-pod baselines; the multi-pod pass is
a compile-proof, not a roofline source) and prints, per cell:
  three roofline terms (s), dominant bottleneck, MODEL_FLOPS, useful-flops
  ratio, and one-line "what would move the dominant term".
Calibrated numbers (per-layer extrapolation of unrolled variants) are used —
raw scanned-HLO numbers undercount loop bodies (see repro.launch.dryrun).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks import common

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

_ADVICE = {
    ("compute",): "raise arithmetic efficiency: fused/flash attention kernel,"
                  " drop causal-masked waste, reduce remat recompute",
    ("memory",): "cut bytes: fuse elementwise chains (TPU does), bf16 "
                 "activations, grouped-KV decode reads, smaller remat policy",
    ("collective",): "cut collective bytes: ZeRO-1 reduce-scatter, overlap "
                     "grad all-reduce with backward, shard more params",
}


def load_cells(mesh: str = "single_pod", tag: str = ""):
    cells = []
    suffix = f"__{mesh}" + (f"__{tag}" if tag else "") + ".json"
    for p in sorted(DRYRUN.glob(f"*{suffix}")):
        if p.name.count("__") != suffix.count("__") + 1:
            continue  # skip tagged variants when untagged requested
        rec = json.loads(p.read_text())
        cells.append(rec)
    return cells


def main():
    t0 = time.time()
    cells = load_cells("single_pod")
    ok = [c for c in cells if "error" not in c]
    print("# Roofline table — single-pod (16,16)=256 chips, per-chip terms")
    print("arch,shape,kind,compute_s,memory_s,collective_s,bottleneck,"
          "model_gflops_chip,useful_flops_ratio,roofline_fraction")
    n_bound = {"compute": 0, "memory": 0, "collective": 0}
    for c in ok:
        cal = c.get("calibrated", {})
        r = cal.get("roofline", c["roofline"])
        ufr = cal.get("useful_flops_ratio") or 0.0
        n_bound[r["bottleneck"]] += 1
        print(f"{c['arch']},{c['shape']},{c['kind']},"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e},{r['bottleneck']},"
              f"{c['model_flops_per_chip'] / 1e9:.1f},"
              f"{ufr:.3f},{r['roofline_fraction']:.3f}")
    multi = [c for c in load_cells("multi_pod") if "error" not in c]
    us = (time.time() - t0) * 1e6 / max(len(ok), 1)
    common.emit(
        "roofline_table", us,
        f"cells_ok={len(ok)};multi_pod_ok={len(multi)};"
        f"bound_compute={n_bound['compute']};bound_memory={n_bound['memory']};"
        f"bound_collective={n_bound['collective']}")
    for b, adv in _ADVICE.items():
        print(f"# advice[{b[0]}]: {adv}")
    return ok


if __name__ == "__main__":
    main()
