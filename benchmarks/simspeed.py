"""Simulation-throughput benchmark: the perf trajectory of the cycle sim.

Measures, per registered policy, steady-state simulation throughput
(simulated cycles × workloads per wall-second) and trace+compile time
(first call minus steady call); the cold-sweep wall-clock of the stackable
`CentralizedPolicy` family both stacked (one XLA program) and per-policy
("stacked_family" section); and the wall-clock of the fig4-equivalent
sweep (every registry policy, parity config, alone baselines included,
force-run through `common.run_sweep` into a throwaway cache dir). The
sweep also counts compiled XLA programs and asserts the one-program
property for the stacked family — `make bench-smoke` is the CI gate
against accidental de-stacking.

The "event_skip" section measures the variable-step driver: steady-state
wall-clock of the ticked scan vs the event-skipping while_loop on the
bursty archetype family (idle-dominated — the skip payoff) and on the
standard fig4-style mix (saturated — documents the per-step witness
overhead that keeps the skipping driver opt-in; the standard sweeps
tick), plus per-archetype skip ratios from the `sim_steps` metric. Throughput is reported on two
bases: ``cycles_per_s`` (simulated cycle-workloads per wall-second —
what cycle skipping improves) and ``steps_per_s`` (processed loop steps
per wall-second — per-step cost, which skipping must NOT regress), so
speedups are never conflated with skip ratio.

Results land in ``BENCH_simspeed.json`` at the repo root. The file keeps
two sections: ``baseline`` (the first measurement ever recorded — the
pre-optimization reference) and ``current`` (refreshed on every full-scale
run), plus the speedup ratio between them. Quick/smoke runs never touch
the file, so the baseline comparison stays apples-to-apples.

Usage:
    PYTHONPATH=src python -m benchmarks.simspeed            # full, writes
    PYTHONPATH=src python -m benchmarks.simspeed --smoke    # tiny, no write
"""
from __future__ import annotations

import argparse
import json
import platform
import tempfile
import time
from pathlib import Path
from typing import Dict, Sequence

import jax
import numpy as np

from benchmarks import common
from repro import compat
from repro.core import simulator as sim
from repro.core import workloads as wl

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_simspeed.json"

# canonical scales — change them only together with a fresh baseline
SWEEP_SCALE = dict(n_per_cat=15, n_cycles=16_000, warmup=2_000)
POLICY_SCALE = dict(n_per_cat=4, n_cycles=3_000, warmup=500)
# stacked-vs-per-policy family comparison: a COLD sweep of the stackable
# CentralizedPolicy family both ways. Deliberately compile-dominated (short
# cycle counts) — amortizing the per-policy trace+compile is exactly what
# the stacked path is for. Must not collide with SWEEP_SCALE's static args
# or the later all-policy sweep would find warm jit caches.
FAMILY_SCALE = dict(n_per_cat=4, n_cycles=2_000, warmup=500)
# ticked vs event-skipping driver comparison: steady-state (both modes
# compiled before timing), long cycle counts so the per-step loop cost
# dominates the dispatch overhead. Distinct static args again, so neither
# mode's program pollutes the sweep/family compile counts.
EVENT_SCALE = dict(n_per_cat=4, n_cycles=12_000, warmup=1_500)


def measure_per_policy(policies: Sequence[str], n_per_cat: int,
                       n_cycles: int, warmup: int) -> Dict[str, Dict]:
    """First call (trace+compile+run) vs steady call, per policy."""
    cfg = common.parity_config()
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    pool, active = wl.pool_batch(cfg, wls)
    W = len(wls)
    out = {}
    for pol in policies:
        t0 = time.time()
        sim.simulate(cfg, pol, pool, active, n_cycles, warmup)
        t1 = time.time()
        m = sim.simulate(cfg, pol, pool, active, n_cycles, warmup)
        t2 = time.time()
        # `cycles_per_s` counts SIMULATED cycles: under the event-skipping
        # driver it credits jumped idle spans. `steps_per_s` counts cycles
        # the loop actually processed (scaled by the measured-window skip
        # ratio) — the per-step cost basis, immune to skip-ratio inflation.
        cps = (n_cycles + warmup) * W / (t2 - t1)
        ratio = 1.0 - float(np.mean(m["sim_steps"])) / n_cycles
        out[pol] = {
            "first_call_s": round(t1 - t0, 3),
            "steady_s": round(t2 - t1, 3),
            "compile_s": round((t1 - t0) - (t2 - t1), 3),
            "cycles_per_s": round(cps, 1),
            "steps_per_s": round(cps * (1.0 - ratio), 1),
            "skip_ratio": round(ratio, 3),
        }
    return out


def _xla_program_counts() -> Dict[str, int]:
    """Distinct compiled XLA programs per sim entry point (jit cache sizes)."""
    return {"stacked": compat.jit_cache_size(sim._sim_batch_stacked),
            "per_policy": compat.jit_cache_size(sim._sim_batch)}


def _cold_sweep(cfg, policies, wls, n_cycles, warmup, stacked, tag):
    """force-run `run_sweep` into a throwaway cache dir; returns wall_s."""
    saved_dir = common.EXP_DIR
    with tempfile.TemporaryDirectory(prefix="simspeed_") as tmp:
        common.EXP_DIR = Path(tmp)
        try:
            t0 = time.time()
            common.run_sweep(cfg, policies, wls, n_cycles=n_cycles,
                             warmup=warmup, tag=tag, force=True,
                             stacked=stacked)
            return time.time() - t0
        finally:
            common.EXP_DIR = saved_dir


def measure_sweep(policies: Sequence[str], n_per_cat: int, n_cycles: int,
                  warmup: int) -> Dict:
    """Fig4-equivalent sweep wall-clock: all policies, parity config,
    alone baselines included, cold caches (throwaway cache dir)."""
    cfg = common.parity_config()
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    n_alone = len(wl.alone_batch(cfg)[2])
    before = _xla_program_counts()
    wall = _cold_sweep(cfg, policies, wls, n_cycles, warmup, stacked=True,
                       tag="simspeed")
    after = _xla_program_counts()
    cycw = (n_cycles + warmup) * (len(wls) + n_alone) * len(policies)
    return {
        "wall_s": round(wall, 2),
        "cycle_workloads": cycw,
        "cycles_per_s": round(cycw / wall, 1),
        "n_workloads": len(wls), "n_alone": n_alone,
        "n_cycles": n_cycles, "warmup": warmup,
        "policies": list(policies),
        "xla_programs": {k: after[k] - before[k] for k in after},
        "n_stackable": len(sim.stackable_names(cfg, policies)),
    }


def measure_nclass_smoke(n_cycles: int = 240, warmup: int = 60) -> Dict:
    """3-class mix (CPU+GPU+HWA): the stackable family must still compile
    as ONE XLA program with class ids + deadline streams in the pool.
    Tiny fixed scale — this is a compile-count gate, not a throughput
    measurement (the distinct config keeps its jit cache entry separate
    from the 2-class scales)."""
    cfg = common.parity_config(n_cpu=4, n_hwa=2)
    fam = sim.stackable_names(cfg)
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=1, n_hwa=cfg.n_hwa)
    pool, active = wl.pool_batch(cfg, wls)
    before = compat.jit_cache_size(sim._sim_batch_stacked)
    sim.simulate_stacked(cfg, fam, pool, active, n_cycles, warmup)
    after = compat.jit_cache_size(sim._sim_batch_stacked)
    return {"policies": list(fam), "n_hwa": cfg.n_hwa,
            "xla_programs": after - before}


def measure_knob_grid(n_cycles: int = 260, warmup: int = 60) -> Dict:
    """Design-grid smoke: the whole (policy x knob-variant) grid — every
    stackable policy crossed with value-knob variants plus a period-knob
    variant (per-slice static config) — must compile as ONE stacked XLA
    program (`sim.simulate_stacked_grid`). Tiny fixed scale: this is a
    compile-count gate for the batched-knob path (`make bench-dse`), not a
    throughput measurement."""
    cfg = common.parity_config()
    variants = [
        {},
        {"cpu_reserve": 0.25},
        {"cpu_reserve": 0.75, "energy_pd_idle": 16},
        # period-like knobs ride the per-slice static config, value-like
        # knobs the batched axis — one program must cover the mix
        {"atlas_epoch": 1500, "tcm_quantum": 800, "cpu_reserve": 0.625},
    ]
    fam = list(sim.stackable_names(cfg))
    slices = [(p, ov) for p in fam for ov in variants]
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=1)
    pool, active = wl.pool_batch(cfg, wls)
    before = compat.jit_cache_size(sim._sim_batch_stacked_grid)
    t0 = time.time()
    sim.simulate_stacked_grid(cfg, slices, pool, active, n_cycles, warmup)
    wall = time.time() - t0
    after = compat.jit_cache_size(sim._sim_batch_stacked_grid)
    t0 = time.time()
    sim.simulate_stacked_grid(cfg, slices, pool, active, n_cycles, warmup)
    steady = time.time() - t0
    return {"policies": fam, "n_variants": len(variants),
            "grid_points": len(slices), "wall_s": round(wall, 2),
            "steady_s": round(steady, 3),
            "compile_s": round(wall - steady, 3),
            "xla_programs": after - before}


def measure_stacked_family(n_per_cat: int, n_cycles: int, warmup: int
                           ) -> Dict:
    """Cold-sweep wall-clock for the stackable CentralizedPolicy family,
    stacked (one XLA program) vs per-policy (one program each)."""
    cfg = common.parity_config()
    fam = list(sim.stackable_names(cfg))
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    out = {"policies": fam, "n_workloads": len(wls),
           "n_cycles": n_cycles, "warmup": warmup}
    for mode, stacked in (("stacked", True), ("per_policy", False)):
        out[f"{mode}_wall_s"] = round(
            _cold_sweep(cfg, fam, wls, n_cycles, warmup, stacked,
                        tag=f"simspeed_{mode}"), 2)
    out["stacked_speedup_x"] = round(
        out["per_policy_wall_s"] / out["stacked_wall_s"], 2)
    return out


def measure_event_skip(n_per_cat: int, n_cycles: int, warmup: int) -> Dict:
    """Ticked vs event-skipping driver, steady state, stacked family.

    Bursty archetype family: one stacked dispatch PER archetype (a batch
    would couple them — the shared while_loop runs until the least-skippy
    row finishes, capping the family win at the worst row's ratio), timed
    both ways after both modes are compiled; the family figure is the
    summed wall-clock. Standard fig4-style mix: one batched dispatch both
    ways — saturated traffic skips almost nothing, so this documents the
    witness overhead that makes the skipping driver OPT-IN
    (`sim.DEFAULT_SKIP`). Compile-count deltas per mode are recorded so
    the smoke gate can assert the skipping family still rides ONE stacked
    XLA program. Skip ratios come from the `sim_steps` metric
    (family-common: the stacked slices share one loop).
    """
    t_sec = time.time()
    out = {"n_cycles": n_cycles, "warmup": warmup}
    cfgb = common.parity_config(n_cpu=4, n_hwa=2)
    famb = list(sim.stackable_names(cfgb))
    bpool, bact = wl.bursty_batch(cfgb)
    rows = [({k: v[i:i + 1] for k, v in bpool.items()}, bact[i:i + 1])
            for i in range(len(wl.BURSTY_ARCHETYPES))]
    programs, compiles = {}, {}
    for mode, skip in (("ticked", False), ("skipping", True)):
        before = compat.jit_cache_size(sim._sim_batch_stacked)
        t0 = time.time()
        sim.simulate_stacked(cfgb, famb, *rows[0], n_cycles, warmup,
                             skip=skip)
        compiles[mode] = time.time() - t0   # first call: trace+compile+run
        programs[mode] = compat.jit_cache_size(sim._sim_batch_stacked) \
            - before
    per, tick_total, skip_total = {}, 0.0, 0.0
    for (p1, a1), name in zip(rows, wl.BURSTY_ARCHETYPES):
        t0 = time.time()
        sim.simulate_stacked(cfgb, famb, p1, a1, n_cycles, warmup,
                             skip=False)
        wt = time.time() - t0
        t0 = time.time()
        m = sim.simulate_stacked(cfgb, famb, p1, a1, n_cycles, warmup,
                                 skip=True)
        ws = time.time() - t0
        ratio = 1.0 - float(m[famb[0]]["sim_steps"][0]) / n_cycles
        per[name] = {"ticked_wall_s": round(wt, 3),
                     "skipping_wall_s": round(ws, 3),
                     "speedup_x": round(wt / max(ws, 1e-9), 2),
                     "skip_ratio": round(ratio, 3)}
        tick_total += wt
        skip_total += ws
    out["bursty"] = {
        "policies": famb,
        "archetypes": per,
        "skip_ratio": {a: per[a]["skip_ratio"] for a in per},
        "ticked_wall_s": round(tick_total, 3),
        "skipping_wall_s": round(skip_total, 3),
        "speedup_x": round(tick_total / max(skip_total, 1e-9), 2),
        "ticked_xla_programs": programs["ticked"],
        "skipping_xla_programs": programs["skipping"],
        # first-call wall (trace+compile+run) per mode; the steady walls
        # above subtract out as the compile-time share for the CI artifact
        "ticked_first_call_s": round(compiles["ticked"], 3),
        "skipping_first_call_s": round(compiles["skipping"], 3),
        "ticked_compile_s": round(
            compiles["ticked"] - per[wl.BURSTY_ARCHETYPES[0]]
            ["ticked_wall_s"], 3),
        "skipping_compile_s": round(
            compiles["skipping"] - per[wl.BURSTY_ARCHETYPES[0]]
            ["skipping_wall_s"], 3),
    }

    cfgs = common.parity_config()
    fams = list(sim.stackable_names(cfgs))
    wls = wl.make_workloads(cfgs.n_cpu, n_per_cat=n_per_cat)
    pool, active = wl.pool_batch(cfgs, wls)
    sres = {"n_workloads": len(wls)}
    for mode, skip in (("ticked", False), ("skipping", True)):
        before = compat.jit_cache_size(sim._sim_batch_stacked)
        t0 = time.time()
        sim.simulate_stacked(cfgs, fams, pool, active, n_cycles, warmup,
                             skip=skip)
        sres[f"{mode}_first_call_s"] = round(time.time() - t0, 3)
        sres[f"{mode}_xla_programs"] = \
            compat.jit_cache_size(sim._sim_batch_stacked) - before
        t0 = time.time()
        m = sim.simulate_stacked(cfgs, fams, pool, active, n_cycles,
                                 warmup, skip=skip)
        sres[f"{mode}_wall_s"] = round(time.time() - t0, 3)
        sres[f"{mode}_compile_s"] = round(
            sres[f"{mode}_first_call_s"] - sres[f"{mode}_wall_s"], 3)
    sres["speedup_x"] = round(sres["ticked_wall_s"]
                              / max(sres["skipping_wall_s"], 1e-9), 2)
    sres["mean_skip_ratio"] = round(
        1.0 - float(np.mean(m[fams[0]]["sim_steps"])) / n_cycles, 3)
    out["fig4_mix"] = sres
    out["wall_s"] = round(time.time() - t_sec, 2)
    return out


def measure_telemetry_gate(n_cycles: int = 280, warmup: int = 70) -> Dict:
    """Flight-recorder contract gates (ROADMAP "Telemetry contract").

    OFF must add ZERO primitives to the per-cycle jaxpr: telemetry's entry
    points are poisoned and both drivers re-traced — any residual call
    raises (the poisoned-entry pattern from tests/test_telemetry.py). ON
    must keep the stacked family at ONE XLA program (distinct static args
    keep its jit cache entry separate from every other scale here), and
    must strictly grow the step jaxpr (non-vacuity: the gate separates).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import policy as policy_api
    from repro.core import telemetry

    cfg_off = common.parity_config(n_cpu=3)
    cfg_on = cfg_off.replace(telemetry_enabled=True, telemetry_window=8,
                             telemetry_epoch=64)

    def n_prims(cfg, poisoned):
        saved = {f: getattr(telemetry, f)
                 for f in ("snapshot", "tick_accrue", "skip_accrue")}

        def boom(*a, **k):
            raise AssertionError("telemetry entry point reached while off")
        try:
            if poisoned:
                for f in saved:
                    setattr(telemetry, f, boom)
            rcfg, pol, carry = sim._init(cfg, "frfcfs")
            pool = sim.prepare_pool(
                {"mpki": np.ones((rcfg.n_src,), np.float32),
                 "inst_per_miss": np.full((rcfg.n_src,), 100.0, np.float32),
                 "rbl": np.full((rcfg.n_src,), 0.5, np.float32),
                 "blp": np.ones((rcfg.n_src,), np.int32),
                 "is_gpu": np.zeros((rcfg.n_src,), bool)},
                (rcfg.n_src,))
            active = jnp.ones((rcfg.n_src,), bool)
            step = policy_api.make_step(rcfg, pol, pool, active)
            jx = jax.make_jaxpr(step)(carry, jnp.int32(5))
            skip = policy_api.make_skip_step(rcfg, pol, pool, active)
            jax.make_jaxpr(lambda c, t: skip(c, t, jnp.int32(400))
                           )(carry, jnp.int32(5))
            return sum(1 for _ in compat.walk_primitives(jx.jaxpr))
        finally:
            for f, fn in saved.items():
                setattr(telemetry, f, fn)

    off_prims = n_prims(cfg_off, poisoned=True)   # raises if gate leaks
    on_prims = n_prims(cfg_on, poisoned=False)
    fam = list(sim.stackable_names(cfg_on))
    wls = wl.make_workloads(cfg_on.n_cpu, n_per_cat=1)
    pool, active = wl.pool_batch(cfg_on, wls)
    before = compat.jit_cache_size(sim._sim_batch_stacked)
    sim.simulate_stacked(cfg_on, fam, pool, active, n_cycles, warmup)
    after = compat.jit_cache_size(sim._sim_batch_stacked)
    return {
        "off_zero_prims": True,                   # poisoned trace survived
        "step_prims_off": off_prims,
        "step_prims_on": on_prims,
        "on_grows_jaxpr": on_prims > off_prims,
        "xla_programs": after - before,
        "policies": fam,
    }


def main(sweep_scale: Dict = None, policy_scale: Dict = None,
         family_scale: Dict = None, event_scale: Dict = None,
         write: bool = True, summary_out: str = None) -> Dict:
    sweep_scale = sweep_scale or SWEEP_SCALE
    policy_scale = policy_scale or POLICY_SCALE
    family_scale = family_scale or FAMILY_SCALE
    event_scale = event_scale or EVENT_SCALE
    policies = list(sim.ALL_POLICIES)
    # the energy subsystem rides the hot loop by default; the compile-count
    # and trace-size gates below are only meaningful if they cover it
    assert common.parity_config().energy_enabled, \
        "bench gate must measure the energy-accounting hot loop"

    t0 = time.time()
    per_policy = measure_per_policy(policies, **policy_scale)
    for pol, r in per_policy.items():
        print(f"  {pol}: steady={r['steady_s']}s compile={r['compile_s']}s "
              f"cycles_per_s={r['cycles_per_s']:,.0f}")
    family = measure_stacked_family(**family_scale)
    print(f"  stacked family ({len(family['policies'])} policies, cold): "
          f"{family['stacked_wall_s']}s stacked vs "
          f"{family['per_policy_wall_s']}s per-policy "
          f"({family['stacked_speedup_x']}x)")
    sweep = measure_sweep(policies, **sweep_scale)
    print(f"  sweep: {sweep['wall_s']}s -> {sweep['cycles_per_s']:,.0f} "
          f"cycle-workloads/s; xla_programs={sweep['xla_programs']}")
    nclass = measure_nclass_smoke()
    print(f"  3-class smoke ({len(nclass['policies'])} policies, "
          f"{nclass['n_hwa']} HWAs): xla_programs={nclass['xla_programs']}")
    knob_grid = measure_knob_grid()
    print(f"  knob grid ({knob_grid['grid_points']} points = "
          f"{len(knob_grid['policies'])} policies x "
          f"{knob_grid['n_variants']} variants): "
          f"xla_programs={knob_grid['xla_programs']} "
          f"in {knob_grid['wall_s']}s")
    event = measure_event_skip(**event_scale)
    print(f"  event skip: bursty {event['bursty']['ticked_wall_s']}s ticked"
          f" vs {event['bursty']['skipping_wall_s']}s skipping "
          f"({event['bursty']['speedup_x']}x, "
          f"ratios={event['bursty']['skip_ratio']}); fig4 mix "
          f"{event['fig4_mix']['speedup_x']}x at mean skip ratio "
          f"{event['fig4_mix']['mean_skip_ratio']}")
    tel = measure_telemetry_gate()
    print(f"  telemetry: off adds 0 prims (poisoned trace ok, "
          f"{tel['step_prims_off']} prims), on grows jaxpr to "
          f"{tel['step_prims_on']} and stays {tel['xla_programs']} "
          f"stacked program")

    current = {
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "sweep_scale": dict(sweep_scale),
            "policy_scale": dict(policy_scale),
            "family_scale": dict(family_scale),
            "event_scale": dict(event_scale),
        },
        "per_policy": per_policy,
        "stacked_family": family,
        "sweep": sweep,
        "nclass_smoke": nclass,
        "knob_grid": knob_grid,
        "event_skip": event,
        "telemetry_gate": tel,
    }
    # CI gate (bench-smoke): the whole stackable family must ride ONE XLA
    # program through the sweep — with energy accounting enabled (asserted
    # above) — and only the SMS-style protocols may fall back to per-policy
    # compiles. Catches accidental de-stacking, including by energy state.
    # The summary artifact is written BEFORE the asserts, with the measured
    # gate outcomes, so a failed gate is diagnosable from the artifact.
    n_fallback = len(policies) - sweep["n_stackable"]
    gates = {
        "energy_enabled": True,                    # asserted at entry
        "stacked_one_program": sweep["xla_programs"]["stacked"] == 1,
        "per_policy_fallbacks_ok":
            sweep["xla_programs"]["per_policy"] == n_fallback,
        "expected_fallbacks": n_fallback,
        "nclass_one_program": nclass["xla_programs"] == 1,
        # the batched-knob design grid (bench-dse) is ONE stacked program
        "dse_one_program": knob_grid["xla_programs"] == 1
            and knob_grid["grid_points"] >= 24,
        # the event-skipping driver is a second while_loop body, not a
        # second program per policy: one stacked compile per batch shape
        "skip_one_program":
            event["bursty"]["skipping_xla_programs"] == 1
            and event["fig4_mix"]["skipping_xla_programs"] == 1,
        # idle_cpu is the archetype whose spans stay long even at smoke
        # cycle counts; a collapse here means witnesses got conservative
        "bursty_min_skip_ratio_ok":
            event["bursty"]["skip_ratio"]["idle_cpu"] >= 0.5,
        # flight recorder: OFF must add zero primitives to the hot loop
        # (poisoned entry points + an unchanged trace prove it), ON must
        # not de-stack the family — and must actually change the jaxpr,
        # or the zero-prims gate would be vacuous
        "telemetry_off_zero_prims":
            tel["off_zero_prims"] and tel["on_grows_jaxpr"],
        "telemetry_one_program": tel["xla_programs"] == 1,
    }
    if summary_out:
        Path(summary_out).write_text(json.dumps(
            {"current": current, "gates": gates}, indent=1) + "\n")
    assert gates["stacked_one_program"], \
        f"centralized family de-stacked: {sweep['xla_programs']}"
    assert gates["per_policy_fallbacks_ok"], \
        f"expected {n_fallback} per-policy programs: {sweep['xla_programs']}"
    assert gates["nclass_one_program"], \
        f"3-class mix de-stacked the family: {nclass['xla_programs']} programs"
    assert gates["dse_one_program"], \
        f"knob grid de-stacked: {knob_grid['grid_points']} points compiled " \
        f"{knob_grid['xla_programs']} stacked programs, expected 1"
    assert gates["skip_one_program"], \
        "skipping driver de-stacked the family: " \
        f"bursty={event['bursty']['skipping_xla_programs']} " \
        f"fig4={event['fig4_mix']['skipping_xla_programs']} programs"
    assert gates["bursty_min_skip_ratio_ok"], \
        f"idle_cpu skip ratio collapsed: {event['bursty']['skip_ratio']}"
    assert gates["telemetry_off_zero_prims"], \
        f"telemetry gate leaked into the off path: {tel}"
    assert gates["telemetry_one_program"], \
        f"telemetry de-stacked the family: {tel['xla_programs']} programs"
    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    if "baseline" not in data:
        data["baseline"] = current
    data["current"] = current
    cur = current["sweep"]["cycles_per_s"]
    # the baseline ratio is only meaningful at the baseline's own scale;
    # never leave a stale ratio next to a differently-scaled "current"
    same_scale = (data["baseline"]["meta"]["sweep_scale"]
                  == current["meta"]["sweep_scale"])
    if same_scale:
        base = data["baseline"]["sweep"]["cycles_per_s"]
        data["sweep_speedup_vs_baseline_x"] = round(cur / base, 2)
    else:
        data.pop("sweep_speedup_vs_baseline_x", None)
    speedup = data.get("sweep_speedup_vs_baseline_x", "n/a")
    if write:
        BENCH_PATH.write_text(json.dumps(data, indent=1) + "\n")

    us = (time.time() - t0) * 1e6 / max(len(policies), 1)
    common.emit("simspeed", us,
                f"sweep_cycles_per_s={cur:.0f};"
                f"speedup_vs_baseline_x={speedup};written={write}")
    return data


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cycle counts, no BENCH file write — catches "
                    "trace-size/compile-time regressions in CI")
    ap.add_argument("--summary-out", default=None,
                    help="write a JSON run summary to this path (CI artifact)")
    args = ap.parse_args()
    if args.smoke:
        # family/sweep smoke scales must differ in static args, or the
        # sweep's compile-count assertion would find warm jit caches
        main(sweep_scale=dict(n_per_cat=1, n_cycles=300, warmup=100),
             policy_scale=dict(n_per_cat=1, n_cycles=200, warmup=50),
             family_scale=dict(n_per_cat=1, n_cycles=250, warmup=50),
             event_scale=dict(n_per_cat=1, n_cycles=400, warmup=80),
             write=False, summary_out=args.summary_out)
    else:
        main(summary_out=args.summary_out)
