"""Render EXPERIMENTS.md from generated artifacts (dry-run JSONs, sim
caches, perf logs). Narrative sections are authored here; numbers come from
the artifacts so the document can't go stale.

  PYTHONPATH=src python -m benchmarks.write_experiments
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
PERF = ROOT / "experiments" / "perf"

ARCH_ORDER = [
    "xlstm-125m", "command-r-plus-104b", "gemma2-2b", "qwen1.5-4b",
    "qwen1.5-110b", "llama4-scout-17b-a16e", "moonshot-v1-16b-a3b",
    "hymba-1.5b", "llava-next-mistral-7b", "whisper-large-v3",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(arch, shape, mesh):
    p = DRYRUN / f"{arch}__{shape}__{mesh}.json"
    return json.loads(p.read_text()) if p.exists() else None


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_section():
    lines = [
        "## §Dry-run — multi-pod compile proof (deliverable e)",
        "",
        "Every (arch × shape) cell is AOT-lowered **and compiled** for the",
        "single-pod mesh (16,16)=256 chips and the multi-pod mesh",
        "(2,16,16)=512 chips (`pod` axis = DP; see `repro/launch/mesh.py`).",
        "`long_500k` runs only for the sub-quadratic archs per the",
        "assignment (skips documented in DESIGN.md §4).",
        "",
        "| arch | shape | 256c compile | 512c compile | collective ops |",
        "|---|---|---|---|---|",
    ]
    n_ok = n_total = 0
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            sp = load(arch, shape, "single_pod")
            mp = load(arch, shape, "multi_pod")
            if sp is None and mp is None:
                continue
            n_total += 1
            ok_sp = sp is not None and "error" not in sp
            ok_mp = mp is not None and "error" not in mp
            if ok_sp and ok_mp:
                n_ok += 1
            lines.append(
                f"| {arch} | {shape} | "
                f"{'ok %.0fs' % sp['compile_s'] if ok_sp else 'FAIL'} | "
                f"{'ok %.0fs' % mp['compile_s'] if ok_mp else 'FAIL'} | "
                f"{sp.get('n_collective_ops', '-') if ok_sp else '-'} |")
    lines.insert(2, f"**{n_ok}/{n_total} cells pass on both meshes.**")
    lines.append("")
    lines.append("`compiled.memory_analysis()` per cell is recorded in the "
                 "JSON artifacts (host-backend aggregate semantics; "
                 "indicative only). The pipeline-parallel variant "
                 "(2 stages on the pod axis × TP16 × DP16) compiles via "
                 "`repro.launch.dryrun_pp` — see "
                 "`experiments/dryrun/PP__*.json`.")
    return "\n".join(lines)


def roofline_section():
    lines = [
        "## §Roofline — per-cell terms (single-pod, per chip)",
        "",
        "Terms: compute = FLOPs/197 TF, memory = bytes/819 GB/s, collective",
        "= collective-bytes/50 GB/s. FLOPs/bytes are **calibrated**: XLA",
        "counts a `lax.scan` body once, so per-layer costs are measured from",
        "unrolled L=1/L=3 variants and extrapolated to full depth",
        "(`repro/launch/dryrun.py`). `useful` = MODEL_FLOPS (6·N_active·D",
        "train, 2·N·D serve) / compiled FLOPs — the remat/replication/waste",
        "detector. Memory terms are upper bounds: the CPU backend's",
        "bytes-accessed is pre-fusion (TPU fuses elementwise chains).",
        "",
        "| arch | shape | compute_s | memory_s | collect_s | bound | useful"
        " | MFU_bound | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("gemma2-2b", "train_4k"): "attention replicated (8 heads < TP16):"
                                   " batch-reshard attention (§Perf A)",
        ("command-r-plus-104b", "decode_32k"): "12x KV read amplification:"
                                               " grouped-KV decode (§Perf B)",
        ("qwen1.5-110b", "train_4k"): "optimizer-moment traffic: ZeRO-1"
                                      " (§Perf C)",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            sp = load(arch, shape, "single_pod")
            if sp is None or "error" in sp:
                continue
            cal = sp.get("calibrated", {})
            r = cal.get("roofline", sp["roofline"])
            ufr = cal.get("useful_flops_ratio")
            adv = advice.get((arch, shape), {
                "compute": "fuse attention (Pallas flash kernel on TPU); "
                           "cut causal-masked waste",
                "memory": "fusion on TPU; bf16 activations; grouped-KV",
                "collective": "overlap grad all-reduce with backward",
            }.get(r["bottleneck"], ""))
            bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            mfu = (sp["model_flops_per_chip"] / 197e12) / bound_s \
                if bound_s else 0.0
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.2e} | "
                f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                f"{r['bottleneck']} | "
                f"{ufr if ufr is not None else float('nan'):.2f} | "
                f"{mfu:.3f} | {adv} |")
    return "\n".join(lines)


def perf_section():
    lines = [
        "## §Perf — hillclimb log (hypothesis → change → before → after)",
        "",
        "Four cells: (A) worst useful-flops TP-indivisible trainer, (B) the",
        "most collective-bound cell AND the serving/decode cell most",
        "representative of the paper's technique, (C) the largest dense-TP",
        "trainer, (D) the worst roofline fraction in the table. Baselines",
        "are the paper-faithful/naive configurations; every iteration",
        "re-lowers and re-measures. A refuted hypothesis is recorded, not",
        "hidden. Production defaults set from this log:",
        "`decode_grouped=True` (12.9x decode step bound, cell B),",
        "`attn_pad_heads=True` for TP-indivisible archs (2.3-5.4x train",
        "step bound, cells A/D), `remat='full'` kept for memory-bound",
        "trainers (dots refuted, cells A/C), `zero1` only for capacity (its",
        "traffic cost is measured +45%, cell C).",
        "",
    ]
    # scoreboard: per-cell step-time lower bound, baseline -> best iteration
    cells = [json.loads(p.read_text())
             for p in sorted(PERF.glob("cell_*.json"))] if PERF.exists() \
        else []
    if cells:
        lines.append("| cell | arch × shape | baseline bound (s) | "
                     "best (s) | speedup | winning change |")
        lines.append("|---|---|---|---|---|---|")
        for log in cells:
            b = log["baseline"]
            base_bound = max(b["compute_s"], b["memory_s"],
                             b["collective_s"])
            best, best_tag = base_bound, "(baseline)"
            for it in log["iterations"]:
                if "after" not in it:
                    continue
                a = it["after"]
                bound = max(a["compute_s"], a["memory_s"],
                            a["collective_s"])
                if bound < best:
                    best, best_tag = bound, it["tag"]
            lines.append(
                f"| {log['cell']} | {log['arch']} × {log['shape']} | "
                f"{base_bound:.3e} | {best:.3e} | "
                f"**{base_bound / best:.1f}×** | {best_tag} |")
        lines.append("")
        lines.append("Optimized-knob configs re-verified on the 512-chip "
                     "multi-pod mesh (`experiments/dryrun/"
                     "*__multi_pod__opt_*.json`).")
        lines.append("")
    for log in cells:
        b = log["baseline"]
        lines.append(f"### Cell {log['cell']}: {log['arch']} × {log['shape']}"
                     f" — baseline bound: **{b['bottleneck']}**")
        lines.append(f"baseline c/m/x = {b['compute_s']:.3e} / "
                     f"{b['memory_s']:.3e} / {b['collective_s']:.3e} s")
        lines.append("")
        for it in log["iterations"]:
            if "error" in it:
                lines.append(f"- **{it['tag']}** — FAILED: {it['error']}")
                continue
            judged_raw = it.get("judged_on") == "raw"
            d = it["delta_raw_pct"] if judged_raw and "delta_raw_pct" in it \
                else it["delta_pct"]
            src = " (raw scanned terms: calibration CSEs remat away)" \
                if judged_raw else ""
            lines.append(
                f"- **{it['tag']}** ({it['verdict']}, dominant "
                f"{it['dominant_term_delta_pct']:+.1f}%)\n"
                f"  - hypothesis: {it['hypothesis']}\n"
                f"  - measured Δ{src}: compute {d.get('compute_s', 0):+.1f}%, "
                f"memory {d.get('memory_s', 0):+.1f}%, collective "
                f"{d.get('collective_s', 0):+.1f}%")
        lines.append("")
    return "\n".join(lines)


HEADER = """# EXPERIMENTS

Artifacts-backed experiment report. Regenerate with
`PYTHONPATH=src python -m benchmarks.write_experiments`
(tables below are rendered from `experiments/` JSONs; the repro tables from
`python -m benchmarks.run` output, checked into `experiments/*.log`).

## §Repro — the paper's own claims (faithful core)

105 multiprogrammed workloads (7 categories × 15), 8 CPUs + 1 GPU, 2
channels, entry-parity buffers, alone-run-normalized metrics — see
`benchmarks/fig*.py`. Full tables: `experiments/bench_full2.log` /
`bench_output.txt`.

| Paper claim | Paper value | Measured here |
|---|---|---|
| SMS vs TCM fairness (max slowdown) | 4.8× better | {fair_x}× |
| SMS vs TCM weighted speedup | +41.2% | {ws_pct}% (avg over all 7 cats; +17% on H) |
| SMS CPU perf vs TCM | 1.76× | {cpu_x}× |
| SMS GPU perf vs FR-FCFS | ≈1.0× | {gpu_x}× |
| Gains grow with core count | yes | {gain4}% @4c → {gain16}% @16c |
| SMS scales with channels | better than TCM | {ch_sms}× vs {ch_tcm}× (1→8ch) |
| p sweeps CPU↔GPU priority | yes | cpuWS {p_cpu} / gpuSU {p_gpu} (p: 0→1) |
| Area / leakage vs FR-FCFS | −46.3% / −66.7% | −{area}% / −{leak}% (proxy) |
| Beyond paper: LLM serving SMS | — | {serve_fcfs}× fairness vs FCFS @ {serve_thr} throughput |
| Beyond paper: SMS-DASH deadlines (paper §7) | — | {dash_met} frames met vs {sms_met} (SMS) / 0 (FR-FCFS) |
| Beyond paper: adaptive p controller | — | converges to tuned-p fairness from p=0.7 start |

Deviations and why: synthetic Fig-1-calibrated traces instead of
proprietary Pin/GPU traces; 20k-cycle steady-state windows instead of 500M;
8 CPUs / 2 channels for the main table (fig6 sweeps to 16 / fig7 to 8
channels). Orderings and fairness magnitudes reproduce; the weighted-speedup
gain is smaller than the paper's because our baseline schedulers
already run behind a CPU-reserved, admission-limited buffer (paper §4
provisioning), which blunts the worst GPU monopolization FR-FCFS shows in
their unreserved setup.

"""


def repro_numbers():
    txt = ""
    for name in ("bench_output.txt", "experiments/bench_full2.log"):
        p = ROOT / name
        if p.exists():
            txt = p.read_text()
            break

    def grab(pattern, default="?"):
        m = re.search(pattern, txt)
        return m.group(1) if m else default

    return {
        "fair_x": grab(r"fairness_x=([\d.]+)"),
        "ws_pct": grab(r"ws_gain_pct=([\d.-]+)"),
        "cpu_x": grab(r"sms_cpu_vs_tcm_x=([\d.]+)"),
        "gpu_x": grab(r"sms_gpu_vs_frfcfs_x=([\d.]+)"),
        "gain4": grab(r"gain_4c=([\d.-]+)%"),
        "gain16": grab(r"gain_16c=([\d.-]+)%"),
        "ch_sms": grab(r"sms_8ch_vs_1ch_x=([\d.]+)"),
        "ch_tcm": grab(r"tcm_8ch_vs_1ch_x=([\d.]+)"),
        "p_cpu": grab(r"cpu_ws_delta=([+\d.-]+)"),
        "p_gpu": grab(r"gpu_su_delta=([+\d.-]+)"),
        "area": grab(r"area_reduction_pct=([\d.]+)"),
        "leak": grab(r"leakage_reduction_pct=([\d.]+)"),
        "serve_fcfs": grab(r"fairness_vs_fcfs_x=([\d.]+)"),
        "serve_thr": grab(r"throughput_ratio=([\d.]+)"),
        "dash_met": grab(r"dash_met=([\d/]+)"),
        "sms_met": grab(r"sms_met=([\d/]+)"),
    }


def main():
    doc = HEADER.format(**repro_numbers())
    doc += "\n" + dryrun_section() + "\n\n"
    doc += roofline_section() + "\n\n"
    doc += perf_section() + "\n"
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote {ROOT / 'EXPERIMENTS.md'} "
          f"({len(doc.splitlines())} lines)")


if __name__ == "__main__":
    main()
