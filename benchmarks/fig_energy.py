"""Energy / EDP sweep: the paper's "energy-efficient" claim, quantified.

Every registry policy runs the same workload mix at the §5.2 configuration
(16 CPU + 1 GPU, 4 MCs, entry parity) with the command-level DRAM energy
subsystem (`repro.core.energy`) enabled; each policy's measured dynamic +
background DRAM energy is combined with its scheduler-structure static
leakage (`power.scheduler_static_power`) into full-MC energy-per-request
and per-request EDP. The qualitative claim under reproduction: SMS's
row-hit batching plus its CAM-free structures give the lowest energy per
request of the sweep — checked against the best centralized policy.

Output rows: ``policy,energy_per_request_nj,edp,act_frac,background_frac,
static_frac,pd_frac,weighted_bw``.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

from benchmarks import common
from repro.core import metrics as met
from repro.core import power
from repro.core import simulator as sim
from repro.core import workloads as wl

WARMUP = 1_000
COLS = ("energy_per_request", "edp", "act_energy_frac", "background_frac",
        "static_frac", "pd_frac")


def _breakdown(cfg, pol, m, pool, n_cycles) -> Dict[str, float]:
    br = met.energy_breakdown(
        cfg, m, pool, n_cycles,
        static_per_cycle=power.scheduler_static_power(cfg, pol))
    out = {k: float(np.mean(br[k])) for k in br}
    out["bw_total"] = float(np.asarray(m["completed"]).sum(-1).mean()
                            / n_cycles)
    return out


def main(n_per_cat: int = 3, n_cycles: int = 8_000, force: bool = False
         ) -> Dict[str, Dict[str, float]]:
    t0 = time.time()
    cfg = common.parity_config(n_cpu=16, n_channels=4, fifo_size=15,
                               dcs_size=6)
    assert cfg.energy_enabled, "fig_energy needs the energy subsystem on"
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    pool, active = wl.pool_batch(cfg, wls)
    policies = list(sim.ALL_POLICIES)

    # cache RAW sim metrics (config-determined only); the breakdown bakes
    # in power.py model constants, so it is recomputed on every run — a
    # retuned leakage scale can never validate against stale rows
    results: Dict[str, Dict[str, float]] = {}
    todo = []
    for pol in policies:
        key = common._key(cfg, pol, "energy", n_cycles, WARMUP, 7, len(wls))
        path = common.EXP_DIR / f"energy_{pol}_{key}.json"
        if path.exists() and not force:
            m = {k: np.asarray(v) for k, v in
                 json.loads(path.read_text()).items()}
            results[pol] = _breakdown(cfg, pol, m, pool, n_cycles)
        else:
            todo.append((pol, path))

    # stackable family in ONE dispatch, SMS-style protocols async alongside
    stackset = set(sim.stackable_names(cfg, [p for p, _ in todo]))
    fam = [item for item in todo if item[0] in stackset]
    singles = [item for item in todo if item[0] not in stackset]
    pending = []
    if len(fam) > 1:
        dev = sim.simulate_stacked_async(cfg, tuple(p for p, _ in fam), pool,
                                         active, n_cycles, WARMUP)
        box: Dict = {}
        for idx, (pol, path) in enumerate(fam):
            pending.append((pol, path, common._stacked_fetch(dev, idx, box)))
    else:
        singles = fam + singles
    for pol, path in singles:
        dev = sim.simulate_async(cfg, pol, pool, active, n_cycles, WARMUP)
        pending.append((pol, path, lambda dev=dev: {
            k: np.asarray(v) for k, v in dev.items()}))
    for pol, path, fetch in pending:
        m = fetch()
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {k: np.asarray(v).tolist() for k, v in m.items()}, indent=1))
        results[pol] = _breakdown(cfg, pol, m, pool, n_cycles)

    print("# Full-MC energy per request (nJ) + per-request EDP, §5.2 config")
    print("policy," + ",".join(COLS) + ",weighted_bw")
    for pol in policies:
        r = results[pol]
        print(pol + "," + ",".join(f"{r[k]:.3f}" for k in COLS) +
              f",{r['bw_total']:.3f}")

    centralized = [p for p in policies
                   if not p.startswith("sms") and p in results]
    best_c = min(centralized, key=lambda p: results[p]["energy_per_request"])
    sms_epr = results["sms"]["energy_per_request"]
    best_epr = results[best_c]["energy_per_request"]
    assert sms_epr < best_epr, (
        f"SMS energy/request {sms_epr:.2f} nJ did not beat best centralized "
        f"({best_c}: {best_epr:.2f} nJ) — §5.2 energy claim broken")
    us = (time.time() - t0) * 1e6 / max(len(policies), 1)
    common.emit(
        "fig_energy", us,
        f"sms_nj_per_req={sms_epr:.2f};best_centralized={best_c}:"
        f"{best_epr:.2f};sms_savings_pct={100 * (1 - sms_epr / best_epr):.1f};"
        f"paper=sms_lowest_energy")
    return results


if __name__ == "__main__":
    main()
