"""Fig 1: memory characteristics of the trace generators, measured alone.

(a) memory intensity (requests per kilocycle), (b) row-buffer locality
measured at the DRAM (alone), (c) bank-level parallelism (generator stripe).
Validates the synthetic sources sit in the paper's SPEC/GPU ranges.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import simulator as sim
from repro.core import workloads as wl


def main(n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    cfg = common.parity_config()
    pool, active, amap = wl.alone_batch(cfg)
    m = sim.simulate(cfg, "frfcfs", pool, active, n_cycles, 1_000)
    print("# Fig 1 — per-benchmark alone characteristics")
    print("bench,mpkc,rbl,blp")
    gpu_mpkc, cpu_mpkc = [], []
    for name, w in sorted(amap.items()):
        src = cfg.n_cpu if name.startswith("g.") else 0
        mpkc = float(m["mpkc"][w, src])
        rbl = float(m["rbl"][w, src])
        blp = int(pool["blp"][w, src])
        (gpu_mpkc if name.startswith("g.") else cpu_mpkc).append(mpkc)
        print(f"{name},{mpkc:.1f},{rbl:.2f},{blp}")
    ratio = np.mean(gpu_mpkc) / max(np.mean(cpu_mpkc), 1e-9)
    us = (time.time() - t0) * 1e6 / max(len(amap), 1)
    common.emit("fig1_characteristics", us,
                f"gpu_vs_cpu_intensity_x={ratio:.1f};"
                f"paper=gpu_multiple_times_higher")


if __name__ == "__main__":
    main()
