"""SJF-probability sweep: p trades CPU priority against GPU priority (§2).

Rebuilt on the batched-knob path: all six `sjf_prob` points are variant
slices of ONE compiled sweep (`common.run_grid` vmaps the knob axis through
`sim._sim_batch`), where the legacy version re-traced and re-compiled the
simulator once per p (6 programs). The emit line records the compile count
and wall-clock so the delta vs legacy stays visible in BENCH logs.
"""
from __future__ import annotations

import time

from benchmarks import common
from repro import compat
from repro.core import simulator as sim
from repro.core import workloads as wl

PS = (0.0, 0.25, 0.5, 0.75, 0.9, 1.0)
HI_CATS = ("HL", "HML", "HM", "H")


def main(n_per_cat: int = 7, n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    print("# SMS SJF probability sweep (high-intensity workloads)")
    print("p,cpu_ws,gpu_speedup,ws,max_slowdown")
    cfg = common.parity_config()
    wls = [w for w in wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
           if w.category in HI_CATS]
    specs = [("sms", f"p{p}", {"sjf_prob": p}) for p in PS]
    jit0 = compat.jit_cache_size(sim._sim_batch)
    res = common.run_grid(cfg, specs, wls, n_cycles=n_cycles,
                          tag="psweep", force=force)
    xla_programs = compat.jit_cache_size(sim._sim_batch) - jit0
    rows = []
    for p in PS:
        a = res[f"p{p}"]["agg"]
        print(f"{p},{a['cpu_weighted_speedup']:.3f},{a['gpu_speedup']:.3f},"
              f"{a['weighted_speedup']:.3f},{a['max_slowdown']:.2f}")
        rows.append((p, a["cpu_weighted_speedup"], a["gpu_speedup"]))
    wall_s = time.time() - t0
    us = wall_s * 1e6 / max(len(PS), 1)
    cpu_trend = rows[-1][1] - rows[0][1]
    gpu_trend = rows[-1][2] - rows[0][2]
    common.emit("p_sensitivity", us,
                f"cpu_ws_delta={cpu_trend:+.3f};gpu_su_delta={gpu_trend:+.3f};"
                f"xla_programs={xla_programs};legacy_programs=6;"
                f"wall_s={wall_s:.1f};paper=high_p_favors_cpu")
    return rows


if __name__ == "__main__":
    main()
