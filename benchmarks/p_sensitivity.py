"""SJF-probability sweep: p trades CPU priority against GPU priority (§2)."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl

PS = (0.0, 0.25, 0.5, 0.75, 0.9, 1.0)
HI_CATS = ("HL", "HML", "HM", "H")


def main(n_per_cat: int = 7, n_cycles: int = 12_000, force: bool = False):
    t0 = time.time()
    print("# SMS SJF probability sweep (high-intensity workloads)")
    print("p,cpu_ws,gpu_speedup,ws,max_slowdown")
    rows = []
    for p in PS:
        cfg = common.parity_config(sjf_prob=p)
        wls = [w for w in wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
               if w.category in HI_CATS]
        res = common.run_policy(cfg, "sms", wls, n_cycles=n_cycles,
                                tag=f"psweep_{p}", force=force)
        a = res["agg"]
        print(f"{p},{a['cpu_weighted_speedup']:.3f},{a['gpu_speedup']:.3f},"
              f"{a['weighted_speedup']:.3f},{a['max_slowdown']:.2f}")
        rows.append((p, a["cpu_weighted_speedup"], a["gpu_speedup"]))
    us = (time.time() - t0) * 1e6 / max(len(PS), 1)
    cpu_trend = rows[-1][1] - rows[0][1]
    gpu_trend = rows[-1][2] - rows[0][2]
    common.emit("p_sensitivity", us,
                f"cpu_ws_delta={cpu_trend:+.3f};gpu_su_delta={gpu_trend:+.3f};"
                f"paper=high_p_favors_cpu")
    return rows


if __name__ == "__main__":
    main()
