"""Master benchmark harness: one entry per paper table/figure + framework
benches (roofline report, kernels, serving). Prints ``name,us_per_call,
derived`` CSV rows; detailed tables go to stdout above each row.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--force]
       PYTHONPATH=src python -m benchmarks.run --only qos   # QoS family

The ``qos`` entry (benchmarks/fig_qos.py, also `make bench-qos`) sweeps
3-class CPU+GPU+HWA mixes and reports per-class QoS: frame-deadline-met
rate (`dl_met_rate`), per-class p95/p99 request latency from the issue-time
latency histogram (`lat_p99_cpu`, `lat_p99_hwa`, ...), class-masked max
slowdown (`cpu_max_slowdown`, `hwa_max_slowdown`), and `squash_prio`'s
urgent-tier admission count.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(title: str):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload counts / cycles")
    ap.add_argument("--force", action="store_true", help="ignore caches")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    n_per_cat = 4 if args.quick else 15
    n_small = 3 if args.quick else 7
    cycles = 8_000 if args.quick else 16_000
    cycles_small = 6_000 if args.quick else 12_000

    from benchmarks import (buffer_scaling, dash_deadline, fig_energy,
                            fig_pareto, fig_qos, fig1_characteristics,
                            fig4_perf_fairness, fig5_cpu_gpu,
                            fig6_core_scaling, fig7_channel_scaling,
                            p_sensitivity, power_area, simspeed)

    benches = [
        # quick mode measures at reduced scale and must not overwrite the
        # canonical BENCH_simspeed.json baseline comparison
        ("simspeed", lambda: simspeed.main(
            sweep_scale=dict(n_per_cat=2, n_cycles=2_000, warmup=500),
            policy_scale=dict(n_per_cat=2, n_cycles=1_000, warmup=200),
            write=False) if args.quick else simspeed.main()),
        ("fig1", lambda: fig1_characteristics.main(force=args.force)),
        ("fig4", lambda: fig4_perf_fairness.main(n_per_cat, cycles,
                                                 args.force)),
        ("fig5", lambda: fig5_cpu_gpu.main(n_per_cat, cycles, args.force)),
        ("fig6", lambda: fig6_core_scaling.main(n_small, cycles_small,
                                                args.force)),
        ("fig7", lambda: fig7_channel_scaling.main(n_small, cycles_small,
                                                   args.force)),
        ("p_sens", lambda: p_sensitivity.main(n_small, cycles_small,
                                              args.force)),
        ("buffer", lambda: buffer_scaling.main(n_small, cycles_small,
                                               args.force)),
        ("power", lambda: power_area.main(force=args.force)),
        ("energy", lambda: fig_energy.main(2 if args.quick else 3,
                                           cycles_small, args.force)),
        ("dash", lambda: dash_deadline.main(
            8_000 if args.quick else 12_000, args.force)),
        ("qos", lambda: fig_qos.main(3 if args.quick else 4,
                                     8_000 if args.quick else 12_000,
                                     args.force)),
        ("dse", lambda: fig_pareto.main(2 if args.quick else 3,
                                        6_000 if args.quick else 8_000,
                                        args.force)),
    ]

    # framework benches (present once their modules are built)
    try:
        from benchmarks import roofline_report
        benches.append(("roofline", roofline_report.main))
    except ImportError:
        pass
    try:
        from benchmarks import kernel_bench
        benches.append(("kernels", kernel_bench.main))
    except ImportError:
        pass
    try:
        from benchmarks import serving_bench
        benches.append(("serving", lambda: serving_bench.main(
            quick=args.quick)))
    except ImportError:
        pass

    only = set(args.only.split(",")) if args.only else None
    failed = []
    for name, fn in benches:
        if only and name not in only:
            continue
        _section(name)
        t0 = time.time()
        try:
            fn()
            print(f"[{name} done in {time.time() - t0:.0f}s]")
        except Exception as e:
            failed.append(name)
            print(f"[{name} FAILED: {type(e).__name__}: {e}]")
            traceback.print_exc()
    _section("summary")
    print(f"benchmarks: {len(benches) - len(failed)} ok, "
          f"{len(failed)} failed {failed if failed else ''}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
