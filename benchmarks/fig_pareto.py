"""Design-space exploration: the energy/perf/area Pareto frontier
(`make bench-dse`).

One `common.run_grid` call evaluates the whole design grid — every
stackable centralized policy crossed with a shared set of knob variants
rides a single stacked XLA program (policy and knob variants share the
leading slice axis), and the SMS family sweeps its own knob grid on a
vmapped knob axis. Each grid point is scored on four axes:

  weighted speedup (max) / max slowdown (min) /
  full-MC energy per request (min, via `power.full_mc_energy`) /
  scheduler area (min, via `power.structure_cost`)

and the non-dominated set is the Pareto frontier, optionally filtered by
an ``--area-budget``. The §5.2 claim under reproduction: SMS knob points
appear on the frontier and beat the best centralized policy on
energy/request at a fraction of its scheduler area.

A hillclimb pass (same hypothesis -> measure -> record loop as
`repro.launch.hillclimb`) then perturbs the best SMS point one knob at a
time toward the frontier, logging verdicts to experiments/dse/.

``--smoke`` is the `make bench-smoke` gate: it asserts the >=24-point
(policy x knob-variant) grid compiles as ONE stacked XLA program.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro import compat
from repro.core import power
from repro.core import simulator as sim
from repro.core import workloads as wl

DSE_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dse"

# shared knob variants for the centralized family (cross product with the
# stackable registry = the stacked-grid slice axis)
VARIANTS = (
    ("base", {}),
    ("cpu-lean", {"cpu_reserve": 0.25}),
    ("cpu-rich", {"cpu_reserve": 0.75}),
    ("pd-eager", {"energy_pd_idle": 16}),
)

# SMS knob grid: SJF probability x batch age cap x DASH preemption
SMS_POINTS = [
    {"sjf_prob": p, "batch_age_cap": c, "dash": d}
    for p in (0.5, 0.9) for c in (100, 200) for d in (False, True)
]

# hillclimb refinements applied to the best SMS point (one knob per step)
PLANS = [
    ("pd-eager",
     "Shorter power-down idle threshold (48->16) puts idle ranks down "
     "sooner; predict background energy drops so energy/request falls "
     "with flat weighted speedup.",
     {"energy_pd_idle": 16}),
    ("age-cap-up",
     "A looser stage-1 age cap forms longer row-hit batches; predict "
     "fewer ACT pulses per request (energy/request down) at a small "
     "fairness cost.",
     {"batch_age_cap": 300}),
    ("sjf-strong",
     "sjf_prob -> 1.0 always picks shortest-job CPU batches; predict "
     "weighted speedup up with energy/request flat (paper: high p "
     "favors CPU).",
     {"sjf_prob": 1.0}),
]


def _point_score(cfg, res, n_cycles: int) -> Dict[str, float]:
    """Collapse one grid point into the four Pareto axes."""
    meas = res["measured"]
    dyn = float(np.sum(meas["energy_act"]) + np.sum(meas["energy_rw"]))
    bg = float(np.sum(meas["energy_bg"]) + np.sum(meas["energy_wake"]))
    reqs = float(np.sum(meas["completed"]))
    fe = power.full_mc_energy(cfg, res["policy"], dyn, bg, n_cycles, reqs)
    return {
        "policy": res["policy"],
        "label": res["label"],
        "overrides": res["overrides"],
        "weighted_speedup": res["agg"]["weighted_speedup"],
        "max_slowdown": res["agg"]["max_slowdown"],
        "energy_per_request_nj": fe["energy_per_request_nj"],
        "area": power.structure_cost(cfg, res["policy"])["area"],
    }


def _dominates(a: Dict, b: Dict) -> bool:
    ge = (a["weighted_speedup"] >= b["weighted_speedup"] and
          a["max_slowdown"] <= b["max_slowdown"] and
          a["energy_per_request_nj"] <= b["energy_per_request_nj"] and
          a["area"] <= b["area"])
    gt = (a["weighted_speedup"] > b["weighted_speedup"] or
          a["max_slowdown"] < b["max_slowdown"] or
          a["energy_per_request_nj"] < b["energy_per_request_nj"] or
          a["area"] < b["area"])
    return ge and gt


def pareto_frontier(points: List[Dict]) -> List[Dict]:
    return [p for p in points
            if not any(_dominates(q, p) for q in points if q is not p)]


def _objective(pt: Dict) -> float:
    # perf per nJ: what the hillclimb maximizes (both frontier axes move it)
    return pt["weighted_speedup"] / pt["energy_per_request_nj"]


def hillclimb(cfg, base_pt: Dict, wls, n_cycles: int, force: bool) -> Dict:
    """Hypothesis -> measure -> record: refine the best SMS point."""
    incumbent = dict(base_pt["overrides"])
    best = base_pt
    log = {"baseline": base_pt, "iterations": []}
    for tag, hypothesis, step in PLANS:
        cand = {**incumbent, **step}
        res = common.run_grid(cfg, [("sms", f"hc_{tag}", cand)], wls,
                              n_cycles=n_cycles, tag="dse_hc", force=force)
        pt = _point_score(cfg, res[f"hc_{tag}"], n_cycles)
        delta = (_objective(pt) / _objective(best) - 1.0) * 100.0
        verdict = "confirmed" if delta > 1.0 else (
            "partial" if delta > 0.0 else "refuted")
        log["iterations"].append({
            "tag": tag, "hypothesis": hypothesis, "overrides": cand,
            "point": pt, "objective_delta_pct": delta, "verdict": verdict,
        })
        print(f"[dse/{tag}] ws/nJ {delta:+.1f}% -> {verdict}")
        if delta > 0.0:
            incumbent, best = cand, pt
    log["best"] = best
    DSE_DIR.mkdir(parents=True, exist_ok=True)
    (DSE_DIR / "pareto_hillclimb.json").write_text(json.dumps(log, indent=1))
    return log


def main(n_per_cat: int = 3, n_cycles: int = 8_000, force: bool = False,
         area_budget: float = None, smoke: bool = False,
         strict: bool = False):
    t0 = time.time()
    cfg = common.parity_config()
    assert cfg.energy_enabled, "fig_pareto needs the energy subsystem on"
    if smoke:
        n_per_cat, n_cycles, force = 1, 400, True
    warmup = min(2_000, n_cycles // 4)
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)

    stackable = sim.stackable_names(cfg)
    specs = [(p, f"{p}@{vn}", ov)
             for p in stackable for vn, ov in VARIANTS]
    n_stacked = len(specs)
    specs += [("sms", "sms@" + "_".join(f"{k}={v}" for k, v in pt.items()),
               pt) for pt in SMS_POINTS]

    jit0 = compat.jit_cache_size(sim._sim_batch_stacked_grid)
    tag = "dse_smoke" if smoke else "dse"
    res = common.run_grid(cfg, specs, wls, n_cycles=n_cycles, warmup=warmup,
                          tag=tag, force=force, strict=strict)
    stacked_programs = compat.jit_cache_size(sim._sim_batch_stacked_grid) \
        - jit0

    # tolerant mode: failed slices arrive as error entries — report and
    # score the frontier on the healthy remainder
    failed = [lab for _, lab, _ in specs if "error" in res[lab]]
    for lab in failed:
        print(f"# SKIPPED {lab}: {res[lab]['error']}")
    points = [_point_score(cfg, res[lab], n_cycles)
              for _, lab, _ in specs if "error" not in res[lab]]
    if smoke:
        # bench-smoke gate: the whole centralized grid is ONE XLA program
        assert n_stacked >= 24, f"grid too small: {n_stacked} stacked slices"
        assert stacked_programs == 1, (
            f"{n_stacked}-slice knob grid compiled {stacked_programs} "
            f"stacked programs, expected 1")
        common.emit("fig_pareto_smoke", (time.time() - t0) * 1e6,
                    f"grid_points={len(specs)};stacked_slices={n_stacked};"
                    f"xla_programs={stacked_programs};gate=one_program")
        return points

    budget_pts = [p for p in points
                  if area_budget is None or p["area"] <= area_budget]
    frontier = pareto_frontier(budget_pts)
    front_set = {p["label"] for p in frontier}

    print("# DSE grid: ws / max_slowdown / nJ-per-request / area"
          + (f" (area budget {area_budget:g})" if area_budget else ""))
    print("label,policy,ws,max_slowdown,nj_per_req,area,on_frontier")
    for p in sorted(budget_pts, key=lambda p: -p["weighted_speedup"]):
        print(f"{p['label']},{p['policy']},{p['weighted_speedup']:.3f},"
              f"{p['max_slowdown']:.2f},{p['energy_per_request_nj']:.2f},"
              f"{p['area']:.0f},{int(p['label'] in front_set)}")

    sms_pts = [p for p in points if p["policy"].startswith("sms")]
    cen_pts = [p for p in points if not p["policy"].startswith("sms")]
    best_sms = min(sms_pts, key=lambda p: p["energy_per_request_nj"])
    best_cen = min(cen_pts, key=lambda p: p["energy_per_request_nj"])
    assert best_sms["energy_per_request_nj"] \
        < best_cen["energy_per_request_nj"], (
        f"no SMS point beat the best centralized energy/request "
        f"({best_cen['label']}: {best_cen['energy_per_request_nj']:.2f} nJ "
        f"vs SMS best {best_sms['energy_per_request_nj']:.2f} nJ)")
    sms_on_front = [p for p in frontier if p["policy"].startswith("sms")]
    assert sms_on_front, "no SMS point on the Pareto frontier"

    # refine the best-objective SMS point toward the frontier
    hc = hillclimb(cfg, max(sms_pts, key=_objective), wls, n_cycles, force)

    DSE_DIR.mkdir(parents=True, exist_ok=True)
    (DSE_DIR / "pareto_grid.json").write_text(json.dumps(
        {"points": points, "frontier": sorted(front_set),
         "area_budget": area_budget, "stacked_slices": n_stacked,
         "stacked_xla_programs": stacked_programs}, indent=1))

    us = (time.time() - t0) * 1e6 / max(len(specs), 1)
    common.emit(
        "fig_pareto", us,
        f"grid_points={len(specs)};frontier={len(frontier)};"
        f"sms_on_frontier={len(sms_on_front)};"
        f"sms_best_nj={best_sms['energy_per_request_nj']:.2f};"
        f"cen_best_nj={best_cen['energy_per_request_nj']:.2f};"
        f"hc_best_ws_per_nj={_objective(hc['best']):.4f};"
        f"stacked_xla_programs={stacked_programs};"
        f"paper=sms_dominates_on_energy")
    return points


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid run asserting one-program compilation")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--area-budget", type=float, default=None)
    ap.add_argument("--strict", dest="strict", action="store_true",
                    help="re-raise on the first failing grid slice")
    ap.add_argument("--tolerant", dest="strict", action="store_false",
                    help="degrade failing slices and report the healthy "
                         "remainder (default)")
    ap.set_defaults(strict=False)
    args = ap.parse_args()
    main(force=args.force, area_budget=args.area_budget, smoke=args.smoke,
         strict=args.strict)
