"""Shared benchmark harness for the paper-figure reproductions.

Results are cached as JSON under experiments/sim/ keyed by a config hash, so
``python -m benchmarks.run`` is incremental. Alone-run baselines are cached
separately, keyed by (resolved config, policy, cycles) and independent of
the figure tag, so fig4/fig5/fig7 share them instead of re-simulating.

`run_sweep` dispatches every policy's simulation before converting any
result to numpy: JAX's async dispatch keeps the device busy on later
policies while the host post-processes earlier ones, and an uncached alone
baseline is stacked into the same batch as the workload run (one compile,
one dispatch per policy).

Output convention (per repo contract): ``name,us_per_call,derived`` CSV
rows on stdout.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import logging
import os
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import metrics as met
from repro.core import params
from repro.core import policy as policy_api
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import SimConfig

EXP_DIR = Path(__file__).resolve().parents[1] / "experiments" / "sim"
TRACE_DIR = Path(__file__).resolve().parents[1] / "experiments" / "trace"

# Bump when the result schema or the semantics behind cached numbers change
# (new measured columns, metric definition changes, engine behavior fixes).
# The version rides in every cache key — old entries become unreachable —
# AND inside every saved JSON, so `_load_cached`/`evict_stale` can delete
# stale files instead of leaving them to shadow fresh results forever.
CACHE_VERSION = "pr10-telemetry"

# ---------------------------------------------------------------------------
# diagnostics: a leveled logger (REPRO_LOG_LEVEL) + a structured JSONL trace
# (REPRO_TRACE) replace the old raw [sweep-recover] prints. Both write to
# stderr/files only — the CSV contract on stdout stays machine-parsable.
# ---------------------------------------------------------------------------

LOG = logging.getLogger("repro.bench")
if not LOG.handlers:
    _h = logging.StreamHandler(sys.stderr)
    _h.setFormatter(logging.Formatter("[%(name)s %(levelname)s] %(message)s"))
    LOG.addHandler(_h)
    LOG.propagate = False
LOG.setLevel(os.environ.get("REPRO_LOG_LEVEL", "WARNING").upper())

_TRACE_FILE: Optional[Path] = None


def trace_path() -> Optional[Path]:
    """This process's JSONL trace file (None when REPRO_TRACE=0).

    One file per process under experiments/trace/, opened lazily on the
    first event so importing the harness never touches the filesystem.
    """
    global _TRACE_FILE
    if os.environ.get("REPRO_TRACE", "1") == "0":
        return None
    if _TRACE_FILE is None:
        TRACE_DIR.mkdir(parents=True, exist_ok=True)
        _TRACE_FILE = TRACE_DIR / \
            f"trace-{time.strftime('%Y%m%d-%H%M%S')}-{os.getpid()}.jsonl"
    return _TRACE_FILE


def trace_event(event: str, **fields) -> None:
    """Append one structured event ({"ts", "event", ...}) to the trace."""
    path = trace_path()
    if path is None:
        return
    rec = {"ts": round(time.time(), 6), "event": event, **fields}
    try:
        with path.open("a") as f:
            f.write(json.dumps(rec) + "\n")
    except OSError as e:                     # tracing must never kill a sweep
        LOG.debug("trace write failed: %r", e)


@contextlib.contextmanager
def trace_span(event: str, **fields):
    """Span event: one record at exit with the measured `dur_s`."""
    t0 = time.time()
    try:
        yield
    finally:
        trace_event(event, dur_s=round(time.time() - t0, 6), **fields)


@contextlib.contextmanager
def _maybe_profile(label: str):
    """Opt-in `jax.profiler` capture around a dispatch: set
    REPRO_PROFILE_DIR to a directory to record a TensorBoard-loadable
    trace of the stacked program (off by default — profiling is not
    free)."""
    pdir = os.environ.get("REPRO_PROFILE_DIR")
    if not pdir:
        yield
        return
    import jax
    with jax.profiler.trace(os.path.join(pdir, label)):
        yield


def _log_backoff(msg: str) -> None:
    # recovery/degradation breadcrumbs: WARNING level (visible by default)
    # plus a machine-readable degradation-ladder trace event
    LOG.warning("[sweep-recover] %s", msg)
    trace_event("backoff", msg=msg)


def _load_cached(path: Path, force: bool) -> Optional[Dict]:
    """Parsed cache entry, or None. Corrupt and version-stale files are
    EVICTED (deleted) on sight — a stale entry silently shadowing fresh
    semantics is worse than a re-run."""
    if not path.exists():
        return None
    try:
        data = json.loads(path.read_text())
        stale = data.get("cache_version") != CACHE_VERSION
    except (json.JSONDecodeError, OSError):
        data, stale = None, True
    if stale:
        # routine hygiene, not a degradation: INFO level, hidden by default
        LOG.info("evicting stale/corrupt cache entry %s", path.name)
        trace_event("cache_evict", file=path.name)
        path.unlink(missing_ok=True)
        return None
    return None if force else data


def evict_stale() -> List[str]:
    """Sweep experiments/sim/ and delete every cache entry whose embedded
    version is not CACHE_VERSION (or that fails to parse). Returns the
    evicted file names."""
    gone = []
    if EXP_DIR.is_dir():
        for path in sorted(EXP_DIR.glob("*.json")):
            if _load_cached(path, force=True) is None and not path.exists():
                gone.append(path.name)
    return gone


def __getattr__(name: str):
    # Full registry sweep (live view: includes variants like sms_dash and
    # any policy registered after import).
    if name == "POLICIES":
        return sim.ALL_POLICIES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def parity_config(n_cpu: int = 8, n_channels: int = 2, fifo_size: int = 6,
                  dcs_size: int = 4, **kw) -> SimConfig:
    """Centralized buffer sized to SMS entry parity (paper's comparison)."""
    cfg = SimConfig(n_cpu=n_cpu, n_channels=n_channels, fifo_size=fifo_size,
                    dcs_size=dcs_size, **kw)
    entries = cfg.n_src * cfg.fifo_size + cfg.n_banks * cfg.dcs_size
    return cfg.replace(buf_entries=entries)


def resolved_config(cfg: SimConfig, policy: str) -> SimConfig:
    """The config the simulator actually runs: after `policy.configure`."""
    return policy_api.get(policy).configure(cfg)


def resolved_knobs(cfg: SimConfig, policy: str) -> Dict[str, object]:
    """Host-side view of the knob point the policy actually runs at (after
    `configure_knobs` — e.g. sms_dash pins dash=True). Part of every cache
    key: knob variants of one policy may never share a cache entry."""
    rcfg = resolved_config(cfg, policy)
    kn = policy_api.resolve_knobs(rcfg, policy_api.get(policy))
    return {f: np.asarray(getattr(kn, f)).item()
            for f in params.KNOB_FIELDS}


def _key(cfg: SimConfig, policy: str, tag: str, n_cycles: int,
         warmup: int, seed: int, n_per_cat: int) -> str:
    # hash the RESOLVED config AND knob point: a variant policy (e.g.
    # sms_dash, whose configure_knobs pins dash=True) can never collide
    # with its base under any cache-sharing scheme
    blob = json.dumps([CACHE_VERSION, repr(resolved_config(cfg, policy)),
                       sorted(resolved_knobs(cfg, policy).items()),
                       policy, tag, n_cycles, warmup, seed, n_per_cat],
                      sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _alone_key(cfg: SimConfig, policy: str, n_cycles: int,
               warmup: int) -> str:
    blob = json.dumps([CACHE_VERSION, repr(resolved_config(cfg, policy)),
                       sorted(resolved_knobs(cfg, policy).items()),
                       policy, n_cycles, warmup], sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _load_alone(cfg: SimConfig, policy: str, n_cycles: int, warmup: int,
                force: bool) -> Optional[Dict[str, float]]:
    path = EXP_DIR / \
        f"alone_{policy}_{_alone_key(cfg, policy, n_cycles, warmup)}.json"
    data = _load_cached(path, force)
    return None if data is None else data["alone"]


def _save_alone(cfg: SimConfig, policy: str, n_cycles: int, warmup: int,
                alone: Dict[str, float]) -> None:
    path = EXP_DIR / \
        f"alone_{policy}_{_alone_key(cfg, policy, n_cycles, warmup)}.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"cache_version": CACHE_VERSION,
                                "alone": alone}, indent=1))


def _stacked_fetch(dev, idx: int, box: Dict):
    """Deferred (W, S) metric slice for policy `idx` of a stacked dispatch.

    The first fetch of the group blocks on the shared device result and
    converts it to numpy ONCE (cached in `box`); siblings reuse the host
    copy instead of re-transferring the whole (W, P, S) stack.
    """
    def fetch() -> Dict[str, np.ndarray]:
        if "m" not in box:
            box["m"] = {k: np.asarray(v) for k, v in dev.items()}
        return {k: v[:, idx] for k, v in box["m"].items()}
    return fetch


def _chunked_run(cfg: SimConfig, polname: str, point: Optional[Dict],
                 batch_pool: Dict[str, np.ndarray],
                 batch_active: np.ndarray, n_cycles: int,
                 warmup: int) -> Dict[str, np.ndarray]:
    """Last rung of the degradation ladder: run the batch one workload row
    at a time (same compiled program reused across rows) and concatenate.
    Isolates a poisoned row — every healthy row still yields its metrics.
    `point` carries value-knob overrides for grid slices (None = defaults).
    """
    W = batch_active.shape[0]
    outs = []
    for i in range(W):
        row_pool = {k: v[i:i + 1] for k, v in batch_pool.items()}
        row_act = batch_active[i:i + 1]
        if point is None:
            m = sim.simulate(cfg, polname, row_pool, row_act, n_cycles,
                             warmup)
            outs.append({k: np.asarray(v) for k, v in m.items()})
        else:
            m = sim.simulate_grid(cfg, polname, [point], row_pool, row_act,
                                  n_cycles, warmup)
            outs.append({k: np.asarray(v)[:, 0] for k, v in m.items()})
    return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}


def _fetch_recover(cfg: SimConfig, polname: str, label: str,
                   point: Optional[Dict], fetch,
                   batch_pool: Dict[str, np.ndarray],
                   batch_active: np.ndarray, n_cycles: int, warmup: int,
                   strict: bool) -> Dict[str, np.ndarray]:
    """Degradation ladder below the (possibly shared) async fetch: retry
    the slice as its own synchronous dispatch, then one workload row at a
    time. `strict` re-raises at the first failure instead of degrading.
    `fetch=None` means the dispatch itself already failed upstream."""
    if fetch is not None:
        try:
            return fetch()
        except Exception as e:
            if strict:
                raise
            _log_backoff(f"{label}: batched fetch failed ({e!r}); "
                         f"retrying as a solo dispatch")
    try:
        if point is None:
            m = sim.simulate(cfg, polname, batch_pool, batch_active,
                             n_cycles, warmup)
            return {k: np.asarray(v) for k, v in m.items()}
        m = sim.simulate_grid(cfg, polname, [point], batch_pool,
                              batch_active, n_cycles, warmup)
        return {k: np.asarray(v)[:, 0] for k, v in m.items()}
    except Exception as e:
        if strict:
            raise
        _log_backoff(f"{label}: solo dispatch failed ({e!r}); "
                     f"retrying per-workload chunks")
    return _chunked_run(cfg, polname, point, batch_pool, batch_active,
                        n_cycles, warmup)


def run_sweep(cfg: SimConfig, policies: Sequence[str],
              workloads: Sequence[wl.Workload], n_cycles: int = 16_000,
              warmup: int = 2_000, seed: int = 7, tag: str = "",
              force: bool = False, stacked: bool = True,
              strict: bool = False) -> Dict[str, Dict]:
    """Alone-normalized per-workload metrics for each policy (cached).

    Uncached policies that opt into the stacked execution path (the
    `CentralizedPolicy` family — see `sim.stackable_names`) run as ONE
    stacked dispatch: their states ride a leading policy axis through a
    single scan, so the whole family costs one trace+compile instead of one
    per policy. The rest (SMS-style protocols, configured variants) keep
    the per-policy path, async-dispatched before any result is blocked on.
    A policy whose alone baseline is uncached gets the alone rows stacked
    into the same batch as the workload rows: one compile + one dispatch
    either way. `stacked=False` forces the per-policy path everywhere
    (benchmarks/simspeed.py uses it to measure the stacking win).

    Fault tolerance: a failing slice degrades down a logged ladder —
    stacked batch halved recursively, then per-policy dispatch, then
    per-workload chunks — and, if everything fails, lands in the result
    dict as ``{"policy": ..., "error": ...}`` (never cached, so a re-run
    retries it) while every healthy slice is persisted per-slice as it
    completes. `strict=True` re-raises at the first failure instead.
    """
    trace_event("sweep_begin", tag=tag or "std", policies=list(policies),
                n_workloads=len(workloads), n_cycles=n_cycles)
    apool, aactive, amap = wl.alone_batch(cfg)
    n_alone = len(amap)
    pool, active = wl.pool_batch(cfg, workloads)
    results: Dict[str, Dict] = {}
    todo = []
    for pol in policies:
        key = _key(cfg, pol, tag or "std", n_cycles, warmup, seed,
                   len(workloads))
        path = EXP_DIR / f"{pol}_{key}.json"
        cached = _load_cached(path, force)
        if cached is not None:
            trace_event("cache_hit", policy=pol, file=path.name)
            results[pol] = cached
            continue
        todo.append((pol, path, _load_alone(cfg, pol, n_cycles, warmup,
                                            force)))

    stackset = set(sim.stackable_names(cfg, [p for p, _, _ in todo])) \
        if stacked else set()
    # group stackable policies by batch composition (alone rows stacked in
    # or not); a group of one has no compile to amortize — per-policy path
    groups: Dict[bool, list] = {}
    singles = []
    for item in todo:
        if item[0] in stackset:
            groups.setdefault(item[2] is None, []).append(item)
        else:
            singles.append(item)
    for need_alone in list(groups):
        if len(groups[need_alone]) == 1:
            singles.extend(groups.pop(need_alone))

    def batch_for(need_alone):
        if need_alone:
            return ({k: np.concatenate([apool[k], pool[k]]) for k in pool},
                    np.concatenate([aactive, active]))
        return pool, active

    pending = []                # (pol, path, alone, fetch, bpool, bactive)

    def solo_dispatch(item):
        pol, path, alone = item
        bp, ba = batch_for(alone is None)
        try:
            # the async-dispatch span covers trace + compile + enqueue
            with trace_span("compile_dispatch", policy=pol, stacked=False):
                dev = sim.simulate_async(cfg, pol, bp, ba, n_cycles, warmup)
            fetch = lambda dev=dev: {k: np.asarray(v)
                                     for k, v in dev.items()}
        except Exception as e:
            if strict:
                raise
            _log_backoff(f"{pol}: async dispatch failed ({e!r}); "
                         f"deferring to the sync fallback ladder")
            fetch = None
        pending.append((pol, path, alone, fetch, bp, ba))

    def stacked_dispatch(items, need_alone):
        # ladder rung 1: a failing stacked trace/compile halves the batch
        # recursively until the culprit is isolated on the solo path
        if len(items) == 1:
            solo_dispatch(items[0])
            return
        bp, ba = batch_for(need_alone)
        try:
            names = [p for p, _, _ in items]
            with trace_span("compile_dispatch", policies=names,
                            stacked=True), _maybe_profile("stacked_sweep"):
                dev = sim.simulate_stacked_async(
                    cfg, tuple(names), bp, ba, n_cycles, warmup)
        except Exception as e:
            if strict:
                raise
            h = len(items) // 2
            _log_backoff(
                f"stacked dispatch {[p for p, _, _ in items]} failed "
                f"({e!r}); halving to {h}+{len(items) - h}")
            stacked_dispatch(items[:h], need_alone)
            stacked_dispatch(items[h:], need_alone)
            return
        box: Dict = {}
        for idx, (pol, path, alone) in enumerate(items):
            pending.append((pol, path, alone,
                            _stacked_fetch(dev, idx, box), bp, ba))

    for need_alone, items in groups.items():
        stacked_dispatch(items, need_alone)
    for item in singles:
        solo_dispatch(item)
    for pol, path, alone, fetch, bp, ba in pending:
        # elapsed_s = this policy's block + post-process segment only; the
        # dispatch/compile phase overlaps across policies and is reported
        # by benchmarks/simspeed.py as sweep wall-clock
        t0 = time.time()
        try:
            with trace_span("fetch", policy=pol):
                m = _fetch_recover(cfg, pol, pol, None, fetch, bp, ba,
                                   n_cycles, warmup, strict)
        except Exception as e:
            if strict:
                raise
            _log_backoff(f"{pol}: ladder exhausted ({e!r}); "
                         f"recording error entry (not cached)")
            results[pol] = {"policy": pol, "error": repr(e)}
            continue
        if alone is None:
            am = {k: v[:n_alone] for k, v in m.items()}
            m = {k: v[n_alone:] for k, v in m.items()}
            alone = wl.alone_perf_lookup(cfg, am, amap)
            _save_alone(cfg, pol, n_cycles, warmup, alone)
            trace_event("alone_baseline", policy=pol, n_rows=n_alone)
        perf = sim.perf_vector(cfg, m, pool)
        rows = [met.workload_metrics(cfg, w, perf[i], alone)
                for i, w in enumerate(workloads)]
        if "lat_hist" in m:
            # per-class QoS columns (tail latency, deadline-met rate) join
            # the speedup/fairness rows, so agg/by_category cover them too
            qb = met.qos_breakdown(cfg, m, pool)
            for i, r in enumerate(rows):
                r.update({k: float(v[i]) for k, v in qb.items()})
        out = {
            "policy": pol,
            "cache_version": CACHE_VERSION,
            "elapsed_s": round(time.time() - t0, 1),
            "alone": alone,
            "rows": rows,
            "categories": [w.category for w in workloads],
            "agg": met.aggregate(rows),
            "by_category": met.by_category(workloads, rows),
            "measured": {k: np.asarray(v).mean(0).tolist()
                         for k, v in m.items()},
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(out, indent=1))
        results[pol] = out
    trace_event("sweep_end", tag=tag or "std",
                errors=[p for p, r in results.items() if "error" in r])
    return {pol: results[pol] for pol in policies}


def run_policy(cfg: SimConfig, policy: str, workloads: Sequence[wl.Workload],
               n_cycles: int = 16_000, warmup: int = 2_000, seed: int = 7,
               tag: str = "", force: bool = False) -> Dict:
    """Alone-normalized per-workload metrics for one policy (cached)."""
    return run_sweep(cfg, [policy], workloads, n_cycles=n_cycles,
                     warmup=warmup, seed=seed, tag=tag, force=force)[policy]


def _grid_key(cfg: SimConfig, policy: str, overrides: Dict, tag: str,
              n_cycles: int, warmup: int, seed: int, n_wl: int) -> str:
    blob = json.dumps([CACHE_VERSION, repr(resolved_config(cfg, policy)),
                       sorted(resolved_knobs(cfg, policy).items()),
                       policy, sorted(overrides.items()), tag,
                       n_cycles, warmup, seed, n_wl],
                      sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def run_grid(cfg: SimConfig, specs: Sequence, workloads: Sequence[wl.Workload],
             n_cycles: int = 16_000, warmup: int = 2_000, seed: int = 7,
             tag: str = "grid", force: bool = False,
             strict: bool = False) -> Dict[str, Dict]:
    """Alone-normalized metrics for a (policy x knob-variant) grid (cached).

    `specs` is a sequence of (policy, label, knob_overrides) triples;
    overrides may mix value-like and period-like knobs. Uncached stackable
    specs run as ONE stacked-grid dispatch (policy and knob variants share
    the leading slice axis — one XLA program for the whole grid); the
    non-stackable rest (the SMS family) groups per (policy, period
    overrides) with value-knob variants on a vmapped knob axis — one
    compiled program per group instead of one per point. Alone-baseline
    rows ride the same batch, so every variant slice gets an alone
    normalization measured at its own knob point.

    Returns {label: result}, parallel to specs; labels must be unique.
    Failing slices degrade down the same logged ladder as `run_sweep`
    (halve the stacked grid, solo dispatch, per-workload chunks) and end
    as uncached ``{"policy", "label", "error"}`` entries unless
    `strict=True`, which re-raises at the first failure.
    """
    specs = [(p, lab, dict(ov)) for p, lab, ov in specs]
    labels = [lab for _, lab, _ in specs]
    if len(set(labels)) != len(labels):
        raise ValueError("duplicate run_grid labels")
    apool, aactive, amap = wl.alone_batch(cfg)
    n_alone = len(amap)
    pool, active = wl.pool_batch(cfg, workloads)
    batch_pool = {k: np.concatenate([apool[k], pool[k]]) for k in pool}
    batch_active = np.concatenate([aactive, active])

    results: Dict[str, Dict] = {}
    todo = []
    for polname, label, ov in specs:
        key = _grid_key(cfg, polname, ov, tag, n_cycles, warmup, seed,
                        len(workloads))
        path = EXP_DIR / f"grid_{polname}_{key}.json"
        cached = _load_cached(path, force)
        if cached is not None:
            trace_event("cache_hit", policy=polname, label=label,
                        file=path.name)
            results[label] = cached
        else:
            todo.append((polname, label, ov, path))

    def _stackable(item):
        per, _ = params.split_overrides(item[2])
        return policy_api.is_stackable(item[0], cfg.replace(**per))

    stacked_items = [it for it in todo if _stackable(it)]
    singles = [it for it in todo if not _stackable(it)]
    pending = []

    def stacked_dispatch(items):
        if len(items) == 1:
            singles.append(items[0])
            return
        try:
            with trace_span("compile_dispatch", stacked=True, grid=True,
                            labels=[it[1] for it in items]), \
                    _maybe_profile("stacked_grid"):
                dev = sim.simulate_stacked_grid_async(
                    cfg, [(p, ov) for p, _, ov, _ in items],
                    batch_pool, batch_active, n_cycles, warmup)
        except Exception as e:
            if strict:
                raise
            h = len(items) // 2
            _log_backoff(
                f"stacked grid dispatch {[it[1] for it in items]} failed "
                f"({e!r}); halving to {h}+{len(items) - h}")
            stacked_dispatch(items[:h])
            stacked_dispatch(items[h:])
            return
        box: Dict = {}
        for idx, it in enumerate(items):
            pending.append((it, _stacked_fetch(dev, idx, box)))

    if len(stacked_items) >= 2:
        stacked_dispatch(stacked_items)
    else:
        singles = stacked_items + singles
    by_group: Dict[tuple, list] = {}
    for it in singles:
        per, _ = params.split_overrides(it[2])
        by_group.setdefault((it[0], tuple(sorted(per.items()))),
                            []).append(it)
    for (polname, per), items in by_group.items():
        gcfg = cfg.replace(**dict(per))
        points = [params.split_overrides(it[2])[1] for it in items]
        try:
            with trace_span("compile_dispatch", policy=polname, grid=True,
                            labels=[it[1] for it in items]):
                dev = sim.simulate_grid_async(gcfg, polname, points,
                                              batch_pool, batch_active,
                                              n_cycles, warmup)
            box = {}
            for idx, it in enumerate(items):
                pending.append((it, _stacked_fetch(dev, idx, box)))
        except Exception as e:
            if strict:
                raise
            _log_backoff(f"grid group {[it[1] for it in items]} dispatch "
                         f"failed ({e!r}); deferring to the fallback "
                         f"ladder")
            pending.extend((it, None) for it in items)

    for (polname, label, ov, path), fetch in pending:
        t0 = time.time()
        per, point = params.split_overrides(ov)
        try:
            with trace_span("fetch", policy=polname, label=label):
                m = _fetch_recover(cfg.replace(**per), polname, label,
                                   point, fetch, batch_pool, batch_active,
                                   n_cycles, warmup, strict)
        except Exception as e:
            if strict:
                raise
            _log_backoff(f"{label}: ladder exhausted ({e!r}); "
                         f"recording error entry (not cached)")
            results[label] = {"policy": polname, "label": label,
                              "error": repr(e)}
            continue
        am = {k: v[:n_alone] for k, v in m.items()}
        m = {k: v[n_alone:] for k, v in m.items()}
        alone = wl.alone_perf_lookup(cfg, am, amap)
        perf = sim.perf_vector(cfg, m, pool)
        rows = [met.workload_metrics(cfg, w, perf[i], alone)
                for i, w in enumerate(workloads)]
        if "lat_hist" in m:
            qb = met.qos_breakdown(cfg, m, pool)
            for i, r in enumerate(rows):
                r.update({k: float(v[i]) for k, v in qb.items()})
        out = {
            "policy": polname,
            "label": label,
            "overrides": ov,
            "cache_version": CACHE_VERSION,
            "elapsed_s": round(time.time() - t0, 1),
            "alone": alone,
            "rows": rows,
            "categories": [w.category for w in workloads],
            "agg": met.aggregate(rows),
            "by_category": met.by_category(workloads, rows),
            "measured": {k: np.asarray(v).mean(0).tolist()
                         for k, v in m.items()},
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(out, indent=1))
        results[label] = out
    return {lab: results[lab] for _, lab, _ in specs}


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def fmt_cat_table(results: Dict[str, Dict], metric: str) -> str:
    cats = list(wl.CATEGORIES)
    lines = ["policy," + ",".join(cats) + ",avg"]
    for pol, res in results.items():
        if "error" in res:
            # tolerant-mode failure entry: keep the row so the partial
            # report stays parallel to the request, but mark it plainly
            lines.append(pol + ",ERROR:" + res["error"].replace(",", ";"))
            continue
        vals = [res["by_category"].get(c, {}).get(metric, float("nan"))
                for c in cats]
        lines.append(pol + "," + ",".join(f"{v:.3f}" for v in vals) +
                     f",{res['agg'][metric]:.3f}")
    return "\n".join(lines)
