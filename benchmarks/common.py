"""Shared benchmark harness for the paper-figure reproductions.

Results are cached as JSON under experiments/sim/ keyed by a config hash, so
``python -m benchmarks.run`` is incremental. Output convention (per repo
contract): ``name,us_per_call,derived`` CSV rows on stdout.
"""
from __future__ import annotations

import hashlib
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import metrics as met
from repro.core import simulator as sim
from repro.core import workloads as wl
from repro.core.params import SimConfig

EXP_DIR = Path(__file__).resolve().parents[1] / "experiments" / "sim"


def __getattr__(name: str):
    # Full registry sweep (live view: includes variants like sms_dash and
    # any policy registered after import).
    if name == "POLICIES":
        return sim.ALL_POLICIES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def parity_config(n_cpu: int = 8, n_channels: int = 2, fifo_size: int = 6,
                  dcs_size: int = 4, **kw) -> SimConfig:
    """Centralized buffer sized to SMS entry parity (paper's comparison)."""
    cfg = SimConfig(n_cpu=n_cpu, n_channels=n_channels, fifo_size=fifo_size,
                    dcs_size=dcs_size, **kw)
    entries = cfg.n_src * cfg.fifo_size + cfg.n_banks * cfg.dcs_size
    return cfg.replace(buf_entries=entries)


def _key(cfg: SimConfig, policy: str, tag: str, n_cycles: int,
         warmup: int, seed: int, n_per_cat: int) -> str:
    blob = json.dumps([repr(cfg), policy, tag, n_cycles, warmup, seed,
                       n_per_cat], sort_keys=True)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def run_policy(cfg: SimConfig, policy: str, workloads: Sequence[wl.Workload],
               n_cycles: int = 16_000, warmup: int = 2_000, seed: int = 7,
               tag: str = "", force: bool = False) -> Dict:
    """Alone-normalized per-workload metrics for one policy (cached)."""
    key = _key(cfg, policy, tag or "std", n_cycles, warmup, seed,
               len(workloads))
    path = EXP_DIR / f"{policy}_{key}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    t0 = time.time()
    apool, aactive, amap = wl.alone_batch(cfg)
    am = sim.simulate(cfg, policy, apool, aactive, n_cycles, warmup)
    alone = wl.alone_perf_lookup(cfg, am, amap)
    pool, active = wl.pool_batch(cfg, workloads)
    m = sim.simulate(cfg, policy, pool, active, n_cycles, warmup)
    perf = sim.perf_vector(cfg, m, pool)
    rows = [met.workload_metrics(cfg, w, perf[i], alone)
            for i, w in enumerate(workloads)]
    out = {
        "policy": policy,
        "elapsed_s": round(time.time() - t0, 1),
        "alone": alone,
        "rows": rows,
        "categories": [w.category for w in workloads],
        "agg": met.aggregate(rows),
        "by_category": met.by_category(workloads, rows),
        "measured": {k: np.asarray(v).mean(0).tolist()
                     for k, v in m.items()},
    }
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    return out


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def fmt_cat_table(results: Dict[str, Dict], metric: str) -> str:
    cats = list(wl.CATEGORIES)
    lines = ["policy," + ",".join(cats) + ",avg"]
    for pol, res in results.items():
        vals = [res["by_category"].get(c, {}).get(metric, float("nan"))
                for c in cats]
        lines.append(pol + "," + ",".join(f"{v:.3f}" for v in vals) +
                     f",{res['agg'][metric]:.3f}")
    return "\n".join(lines)
