"""Interference timelines: the paper's §4 burst-starvation story, rendered.

End-of-run aggregates can't show the episodes the paper argues about: a
duty-cycled accelerator burst arrives, the shared queues fill, CPU requests
stall behind the stream, then the burst drains and service recovers. This
figure runs a GPU-bursty 3-class mix (frame-driven HWA accelerators next to
the CPU cores and GPU — the repo's model of duty-cycled bursts, see
`workloads.bursty_batch`) through the stacked `run_sweep` path with the
flight recorder on, then renders per-epoch timelines for every registry
policy from `metrics.timeline_breakdown`:

  * `occ_cpu` / `lat_cpu` — CPU queue depth and the Little's-law latency
    proxy per epoch: the starvation spikes themselves;
  * `occ_hwa`, `row_hit_rate`, `pd_frac` — what the burst does to the rest
    of the system.

The headline check: SMS's staged admission smooths the bursts. Its
steady-state CPU latency is HIGHER than the centralized policies' (the
per-source FIFOs add batch-formation wait — the paper's acknowledged
trade), so the honest smoothing statistic is the RELATIVE spike
amplitude: (max-over-epochs minus median) / median, over post-warmup
epochs. `--check` enforces that SMS's relative spike stays below the
best centralized policy's (best = highest weighted speedup among the
centralized family); the summary table also shows the burst's shared-
queue footprint (`occ_hwa_max` — roughly halved under SMS, the batches
wait in source FIFOs instead of flooding the scheduler).

Output convention: per-policy summary table and a per-epoch `lat_cpu`
timeline CSV on stdout, then the ``fig_timeline,us_per_call,derived`` row.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks import common
from repro.core import metrics as met
from repro.core import workloads as wl
from repro.core.params import SimConfig


def timeline_config(n_cpu: int = 4, n_hwa: int = 2, n_channels: int = 2,
                    total_cycles: int = 14_000) -> SimConfig:
    """QoS parity config with the flight recorder sized to retain the whole
    run: epoch fixed at 256 cycles, window grown to cover `total_cycles`."""
    epoch = 256
    window = -(-total_cycles // epoch)          # ceil: no epoch falls off
    return common.parity_config(n_cpu=n_cpu, n_channels=n_channels,
                                n_hwa=n_hwa, telemetry_enabled=True,
                                telemetry_epoch=epoch,
                                telemetry_window=window)


def _timelines(cfg: SimConfig, res: dict, total_cycles: int) -> dict:
    m = {"telemetry": np.asarray(res["measured"]["telemetry"])[None],
         "telemetry_epoch": np.asarray([res["measured"]["telemetry_epoch"]])}
    tb = met.timeline_breakdown(cfg, m, total_cycles=total_cycles)
    return {k: v[0] for k, v in tb.items()}


def spike_amplitude(series: np.ndarray, valid: np.ndarray) -> float:
    """Max-over-epochs minus median: how far the worst episode rises above
    steady state (0 for a flat timeline, large for starvation bursts)."""
    v = series[valid]
    return float(v.max() - np.median(v)) if v.size else 0.0


def rel_spike(series: np.ndarray, valid: np.ndarray) -> float:
    """Spike amplitude normalized by the steady-state (median) level, so
    policies with different baseline latencies are comparable: 0.10 means
    the worst episode rises 10% above steady state."""
    v = series[valid]
    if not v.size:
        return 0.0
    med = float(np.median(v))
    return (float(v.max()) - med) / max(med, 1e-9)


def main(n_per_cat: int = 4, n_cycles: int = 12_000, warmup: int = 2_000,
         force: bool = False, strict: bool = False,
         check: bool = False) -> dict:
    t0 = time.time()
    total = warmup + n_cycles
    cfg = timeline_config(total_cycles=total)
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat, seed=13,
                            n_hwa=cfg.n_hwa)
    policies = list(common.POLICIES)
    results = common.run_sweep(cfg, policies, wls, n_cycles=n_cycles,
                               warmup=warmup, tag="timeline", force=force,
                               strict=strict)

    tls, spikes = {}, {}
    print("policy,lat_cpu_spike,lat_cpu_rel_spike,lat_cpu_median,"
          "occ_cpu_max,occ_hwa_max,row_hit_rate,weighted_speedup")
    for pol, res in results.items():
        if "error" in res:
            print(f"{pol},ERROR:{res['error']}")
            continue
        tb = _timelines(cfg, res, total)
        tls[pol] = tb
        # headline stats over post-warmup epochs only: the cold-start ramp
        # (empty queues filling) is not burst interference
        v = tb["valid"] & (tb["epoch"] * cfg.telemetry_epoch >= warmup)
        spikes[pol] = rel_spike(tb["lat_cpu"], v)
        print(f"{pol},{spike_amplitude(tb['lat_cpu'], v):.2f},"
              f"{spikes[pol]:.3f},"
              f"{np.median(tb['lat_cpu'][v]):.2f},"
              f"{tb['occ_cpu'][v].max():.3f},{tb['occ_hwa'][v].max():.3f},"
              f"{np.mean(tb['row_hit_rate'][v]):.3f},"
              f"{res['agg']['weighted_speedup']:.3f}")

    # per-epoch CPU latency proxy, one column per policy: the burst
    # episodes and each policy's smoothing are directly visible
    pols = list(tls)
    ref = tls[pols[0]]
    print("\nepoch_cycle," + ",".join(pols))
    for j in np.where(ref["valid"])[0]:
        row = ",".join(f"{tls[p]['lat_cpu'][j]:.2f}" for p in pols)
        print(f"{int(ref['epoch'][j]) * cfg.telemetry_epoch},{row}")

    centralized = [p for p in pols
                   if not p.startswith("sms") and "error" not in results[p]]
    best = max(centralized,
               key=lambda p: results[p]["agg"]["weighted_speedup"])
    ok = "sms" in spikes and spikes["sms"] <= spikes[best]
    us = (time.time() - t0) * 1e6 / max(len(policies), 1)
    common.emit(
        "fig_timeline", us,
        f"sms_rel_spike={spikes.get('sms', float('nan')):.3f};"
        f"best_centralized={best}:{spikes.get(best, float('nan')):.3f};"
        f"sms_smoother={ok}")
    if check and not ok:
        print(f"fig_timeline: SMS relative spike {spikes.get('sms'):.3f} "
              f"NOT below best centralized ({best}) {spikes.get(best):.3f}",
              file=sys.stderr)
        sys.exit(1)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale: quick plumbing check, not a result")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless SMS's CPU-latency spike amplitude "
                         "is below the best centralized policy's")
    ap.add_argument("--strict", dest="strict", action="store_true",
                    help="re-raise on the first failing sweep slice")
    ap.add_argument("--tolerant", dest="strict", action="store_false",
                    help="degrade failing slices and report the healthy "
                         "remainder (default)")
    ap.set_defaults(strict=False)
    args = ap.parse_args()
    if args.smoke:
        main(n_per_cat=1, n_cycles=2_000, warmup=500, force=args.force,
             strict=args.strict, check=args.check)
    else:
        main(force=args.force, strict=args.strict, check=args.check)
