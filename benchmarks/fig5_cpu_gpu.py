"""Fig 5: CPU weighted speedup and GPU speedup, separately, by category,
for every registered policy."""
from __future__ import annotations

import time

from benchmarks import common
from repro.core import workloads as wl


def main(n_per_cat: int = 15, n_cycles: int = 16_000, force: bool = False):
    cfg = common.parity_config()
    wls = wl.make_workloads(cfg.n_cpu, n_per_cat=n_per_cat)
    t0 = time.time()
    # same tag as fig4: the combined-run cache is shared between figures
    results = common.run_sweep(cfg, common.POLICIES, wls, n_cycles=n_cycles,
                               tag="fig4", force=force)
    us = (time.time() - t0) * 1e6 / max(len(wls) * len(common.POLICIES), 1)

    print("# Fig 5a — CPU weighted speedup by category")
    print(common.fmt_cat_table(results, "cpu_weighted_speedup"))
    print("# Fig 5b — GPU speedup by category")
    print(common.fmt_cat_table(results, "gpu_speedup"))
    sms, tcm = results["sms"]["agg"], results["tcm"]["agg"]
    fr = results["frfcfs"]["agg"]
    cpu_x = sms["cpu_weighted_speedup"] / tcm["cpu_weighted_speedup"]
    gpu_vs_fr = sms["gpu_speedup"] / max(fr["gpu_speedup"], 1e-9)
    common.emit("fig5_cpu_gpu", us,
                f"sms_cpu_vs_tcm_x={cpu_x:.2f};sms_gpu_vs_frfcfs_x="
                f"{gpu_vs_fr:.2f};paper=1.76x/~1.0x")
    return results


if __name__ == "__main__":
    main()
