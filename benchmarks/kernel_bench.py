"""Kernel benches: allclose status + arithmetic-intensity accounting.

This container is CPU-only: Pallas executes in interpret mode, so wall-times
are NOT TPU times. What we report per kernel: correctness vs oracle across a
shape sweep, plus the analytic FLOPs/bytes per call and the implied TPU-v5e
time bound (the kernel-level roofline the BlockSpec tiling targets).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def bench_flash():
    rows = []
    for (B, Hq, Hkv, S, d) in [(1, 8, 8, 1024, 128), (1, 16, 4, 2048, 128),
                               (2, 8, 2, 512, 64)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, Hq, S, d), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, d), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, d), jnp.float32)
        t0 = time.time()
        out = flash_attention(q, k, v, causal=True, interpret=True)
        dt = time.time() - t0
        ref = attention_ref(q, k, v, causal=True)
        err = float(jnp.abs(out - ref).max())
        flops = 4 * B * Hq * S * S * d * 0.5          # causal half
        bytes_ = 2 * (q.size + k.size + v.size + out.size)  # bf16 deploy
        tpu_bound = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
        rows.append((f"flash B{B}H{Hq}/{Hkv}S{S}d{d}", err, flops, bytes_,
                     tpu_bound, dt))
    return rows


def bench_paged():
    rows = []
    for (B, Hq, Hkv, d, page, n_slots, P) in [(8, 8, 2, 128, 64, 8, 128),
                                              (32, 4, 4, 64, 16, 16, 1024)]:
        rng = np.random.RandomState(0)
        lengths = jnp.asarray(rng.randint(page, page * n_slots + 1, (B,)),
                              jnp.int32)
        pt = jnp.asarray(rng.randint(0, P, (B, n_slots)), jnp.int32)
        q = jnp.asarray(rng.randn(B, Hq, d), jnp.float32)
        kp = jnp.asarray(rng.randn(P, Hkv, page, d), jnp.float32)
        vp = jnp.asarray(rng.randn(P, Hkv, page, d), jnp.float32)
        t0 = time.time()
        out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
        dt = time.time() - t0
        ref = paged_attention_ref(q, kp, vp, pt, lengths)
        err = float(jnp.abs(out - ref).max())
        toks = int(np.asarray(lengths).sum())
        flops = 4 * Hq * d * toks
        bytes_ = 2 * 2 * Hkv * d * toks               # read K+V bf16
        tpu_bound = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
        rows.append((f"paged B{B}H{Hq}/{Hkv}d{d}p{page}", err, flops, bytes_,
                     tpu_bound, dt))
    return rows


def bench_mlstm():
    from repro.kernels.mlstm_scan.kernel import mlstm_scan
    from repro.kernels.mlstm_scan.ref import mlstm_scan_ref
    rows = []
    for (B, H, S, dh, chunk) in [(2, 4, 512, 96, 128), (1, 4, 1024, 64, 256)]:
        rng = np.random.RandomState(0)
        q, k, v = (jnp.asarray(rng.randn(B, H, S, dh), jnp.float32)
                   for _ in range(3))
        lf = jnp.asarray(np.log(rng.uniform(0.5, 0.99, (B, H, S))),
                         jnp.float32)
        li = jnp.asarray(rng.randn(B, H, S) * 0.5, jnp.float32)
        t0 = time.time()
        out = mlstm_scan(q, k, v, lf, li, chunk=chunk, interpret=True)
        dt = time.time() - t0
        ref = mlstm_scan_ref(q, k, v, lf, li, chunk=chunk)
        err = float(jnp.abs(out - ref).max())
        flops = 4 * B * H * S * chunk * dh + 2 * B * H * S * dh * dh
        bytes_ = 2 * 4 * B * H * S * dh
        tpu_bound = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
        rows.append((f"mlstm B{B}H{H}S{S}dh{dh}c{chunk}", err, flops,
                     bytes_, tpu_bound, dt))
    return rows


def main():
    t0 = time.time()
    print("# Kernel correctness + TPU-v5e roofline bounds "
          "(interpret-mode check; wall-times are NOT TPU times)")
    print("kernel,max_err,gflops_call,mbytes_call,tpu_bound_us,interp_s")
    worst = 0.0
    for name, err, flops, bytes_, bound, dt in (bench_flash() +
                                                bench_paged() +
                                                bench_mlstm()):
        worst = max(worst, err)
        print(f"{name},{err:.2e},{flops / 1e9:.2f},{bytes_ / 1e6:.2f},"
              f"{bound * 1e6:.1f},{dt:.2f}")
    us = (time.time() - t0) * 1e6 / 7
    common.emit("kernel_bench", us, f"max_err={worst:.2e};status="
                f"{'pass' if worst < 1e-3 else 'FAIL'}")


if __name__ == "__main__":
    main()
